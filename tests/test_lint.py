"""replint self-tests: every rule fires on its bad fixture and never on the
clean twin; suppressions require justification and are counted; the Pallas
auditor covers every kernel file within budget; and the full src/repro tree
lints clean (the CI gate, pinned here so tier-1 catches drift first).

The engine-regression tests lint MUTATED copies of the real serve/fleet
sources — the exact one-line regressions the linter exists to catch (drop a
donated-cache rebind, branch on a traced arg) — so rule coverage is tied to
the real codebase, not just synthetic fixtures.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

from lint import (AST_RULES, DEFAULT_VMEM_BUDGET, audit_paths, lint_files,
                  vmem_table)
from lint.engine import ModuleUnderLint

FIXTURES = ROOT / "tools" / "lint" / "fixtures"
AST_CODES = ["RL101", "RL102", "RL103", "RL104", "RL105"]
PALLAS_CODES = ["RP301", "RP302", "RP303"]


# ---------------------------------------------------------------------------
# fixtures: each rule fires exactly on its bad twin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("code", AST_CODES)
def test_ast_rule_fires_on_bad_fixture_only(code):
    bad, _, _ = lint_files([FIXTURES / f"{code.lower()}_bad.py"], AST_RULES)
    clean, _, _ = lint_files([FIXTURES / f"{code.lower()}_clean.py"],
                             AST_RULES)
    assert {f.code for f in bad} == {code}, [f.render() for f in bad]
    assert clean == [], [f.render() for f in clean]


@pytest.mark.parametrize("code", PALLAS_CODES)
def test_pallas_rule_fires_on_bad_fixture_only(code):
    _, bad = audit_paths([FIXTURES / f"{code.lower()}_bad.py"])
    _, clean = audit_paths([FIXTURES / f"{code.lower()}_clean.py"])
    assert {f.code for f in bad} == {code}, [f.render() for f in bad]
    assert clean == [], [f.render() for f in clean]


def test_fixture_set_is_complete():
    for code in AST_CODES + PALLAS_CODES:
        assert (FIXTURES / f"{code.lower()}_bad.py").exists()
        assert (FIXTURES / f"{code.lower()}_clean.py").exists()


# ---------------------------------------------------------------------------
# suppressions: justified ones count, unjustified ones are findings
# ---------------------------------------------------------------------------

def _lint_source(tmp_path, source):
    p = tmp_path / "mod.py"
    p.write_text(source)
    return lint_files([p], AST_RULES)


def test_justified_suppression_silences_and_is_counted(tmp_path):
    active, suppressed, sups = _lint_source(tmp_path, (
        "import numpy as np\n"
        "x = np.random.randn(4)"
        "  # replint: disable=RL104 -- fixture data, determinism irrelevant\n"
    ))
    assert active == []
    assert [f.code for f in suppressed] == ["RL104"]
    assert len(sups) == 1 and sups[0].justification.startswith("fixture")


def test_unjustified_suppression_is_its_own_finding(tmp_path):
    active, suppressed, _ = _lint_source(tmp_path, (
        "import numpy as np\n"
        "x = np.random.randn(4)  # replint: disable=RL104\n"
    ))
    assert [f.code for f in suppressed] == ["RL104"]
    assert [f.code for f in active] == ["RL000"]   # naked opt-out surfaces


def test_suppression_covers_only_its_own_line(tmp_path):
    active, _, _ = _lint_source(tmp_path, (
        "import numpy as np\n"
        "a = np.random.randn(4)  # replint: disable=RL104 -- seeded upstream\n"
        "b = np.random.randn(4)\n"
    ))
    assert [f.code for f in active] == ["RL104"]
    assert active[0].line == 3


# ---------------------------------------------------------------------------
# regression guards on the REAL sources: the one-line mistakes the linter
# must catch in serve/fleet code, pinned against mutated copies
# ---------------------------------------------------------------------------

def _mutated(tmp_path, src_path: Path, old: str, new: str) -> Path:
    src = src_path.read_text()
    assert old in src, f"pattern drifted out of {src_path.name}: {old!r}"
    out = tmp_path / src_path.name
    out.write_text(src.replace(old, new, 1))
    return out


def test_engine_insert_handoff_use_after_donation_detected(tmp_path):
    """Dropping the ``self.cache =`` rebind on the donated insert→decode
    handoff in serve/engine.py is the exact regression RL101 exists for."""
    bugged = _mutated(
        tmp_path, ROOT / "src" / "repro" / "serve" / "engine.py",
        "                self.cache = self._insert(self.cache, pcache, "
        "slot_ids)",
        "                self._insert(self.cache, pcache, slot_ids)")
    active, _, _ = lint_files([bugged], AST_RULES)
    assert any(f.code == "RL101" and "self.cache" in f.message
               for f in active), [f.render() for f in active]


def test_fleet_vstep_loop_use_after_donation_detected(tmp_path):
    """fleet/batched.py donates the stacked engine state into the vmapped
    step every loop iteration; dropping the rebind must flag RL101."""
    bugged = _mutated(
        tmp_path, ROOT / "src" / "repro" / "fleet" / "batched.py",
        "            state, metrics = self._vstep(state, batch, probs, "
        "masks, weighted)",
        "            out, metrics = self._vstep(state, batch, probs, "
        "masks, weighted)")
    active, _, _ = lint_files([bugged], AST_RULES)
    assert any(f.code == "RL101" and "'state'" in f.message
               for f in active), [f.render() for f in active]


def test_real_sources_are_currently_clean():
    for rel in ("src/repro/serve/engine.py", "src/repro/fleet/batched.py",
                "src/repro/serve/replicated.py", "src/repro/core/engine.py"):
        active, _, _ = lint_files([ROOT / rel], AST_RULES)
        assert active == [], [f.render() for f in active]


# ---------------------------------------------------------------------------
# Pallas auditor over the real kernels
# ---------------------------------------------------------------------------

def test_pallas_audit_covers_every_kernel_file_within_budget():
    kdir = ROOT / "src" / "repro" / "kernels"
    sites, findings = audit_paths([kdir])
    assert findings == [], [f.render() for f in findings]
    kernel_files = {p.name for p in kdir.glob("*.py")
                    if p.name != "__init__.py"}
    # every kernel file with pallas_call sites is audited (ops.py and pad.py
    # are jit wrappers / padding helpers with no kernel launches of their own)
    audited = {s.path.rsplit("/", 1)[-1] for s in sites}
    assert audited == {"ssd.py", "swa.py", "wctma_fused.py", "wcwmed.py",
                       "wreduce.py"}
    assert audited <= kernel_files
    # ... every site has a computed footprint, and all are under budget
    assert len(sites) >= 8
    for s in sites:
        assert s.vmem_bytes > 0, s
        assert s.vmem_bytes <= DEFAULT_VMEM_BUDGET, s


def test_vmem_table_lists_every_site_and_matches_readme():
    kdir = ROOT / "src" / "repro" / "kernels"
    sites, _ = audit_paths([kdir])
    table = vmem_table(sites)
    for s in sites:
        assert f"`{s.func}`" in table
    readme = (kdir / "README.md").read_text()
    assert table in readme, ("kernels/README.md VMEM table is stale — run "
                             "python tools/lint.py --write-kernel-table")


def test_dump_page_invariant_holds_in_serve_cache():
    sites, findings = audit_paths([ROOT / "src" / "repro" / "serve"])
    assert [f for f in findings if f.code == "RP303"] == []


# ---------------------------------------------------------------------------
# the CI gate: full src/repro runs clean through the driver
# ---------------------------------------------------------------------------

def test_full_src_repro_lint_exits_zero(tmp_path):
    report = tmp_path / "lint_report.json"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "lint.py"), "src/repro",
         "--report", str(report)],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    data = json.loads(report.read_text())
    assert data["n_findings"] == 0
    assert data["groups"] == ["ast", "pallas", "docs"]
    assert len(data["kernels"]) >= 8     # the VMEM audit rides the report
    # the per-file rollup accounts for EVERY kernel file, sites or not
    rollup = {k["file"] for k in data["kernel_files"]}
    kdir = ROOT / "src" / "repro" / "kernels"
    assert rollup == {p.name for p in kdir.glob("*.py")
                      if p.name != "__init__.py"}


def test_check_kernel_table_mode_passes_on_current_tree():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "lint.py"), "src/repro",
         "--only", "pallas", "--check-kernel-table"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_parent_map_and_suppression_parsing():
    mod = ModuleUnderLint(FIXTURES / "rl101_bad.py")
    assert mod.suppressions() == []
    fn = [n for n in __import__("ast").walk(mod.tree)
          if n.__class__.__name__ == "FunctionDef"]
    assert fn and mod.enclosing_function(fn[0].body[0]) is fn[0]
