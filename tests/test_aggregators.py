"""Unit + property tests for the weighted robust aggregation rules (§3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (bucketing, c_lambda, krum, weighted_ctma,
                        weighted_cwmed, weighted_cwtm, weighted_gm, weighted_mean,
                        weighted_median_1d, weighted_std)

KEY = jax.random.PRNGKey(0)


def _rand(m, d, seed=0):
    k = jax.random.fold_in(KEY, seed)
    k1, k2 = jax.random.split(k)
    x = jax.random.normal(k1, (m, d))
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    return x, s


# ---------------------------------------------------------------------------
# correctness vs numpy
# ---------------------------------------------------------------------------

def test_cwmed_equal_weights_matches_numpy_median():
    x, _ = _rand(9, 40)
    np.testing.assert_allclose(np.asarray(weighted_cwmed(x)),
                               np.median(np.asarray(x), axis=0), atol=1e-6)


def test_cwmed_even_m_tie_averages_middles():
    x, _ = _rand(8, 40, seed=1)
    np.testing.assert_allclose(np.asarray(weighted_cwmed(x)),
                               np.median(np.asarray(x), axis=0), atol=1e-6)


def test_weighted_median_1d_textbook():
    v = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    s = jnp.asarray([1.0, 1.0, 1.0, 5.0])  # heavy weight on 4
    assert float(weighted_median_1d(v, s)) == 4.0
    s2 = jnp.asarray([5.0, 1.0, 1.0, 1.0])
    assert float(weighted_median_1d(v, s2)) == 1.0


def test_weighted_mean_std():
    x, s = _rand(7, 13)
    xn, sn = np.asarray(x, np.float64), np.asarray(s, np.float64)
    mu = (sn[:, None] * xn).sum(0) / sn.sum()
    np.testing.assert_allclose(np.asarray(weighted_mean(x, s)), mu, rtol=1e-5)
    var = (sn[:, None] * (xn - mu) ** 2).sum(0) / sn.sum()
    np.testing.assert_allclose(np.asarray(weighted_std(x, s)), np.sqrt(var), rtol=1e-4)


def test_gm_stationarity():
    """At the geometric median the weighted subgradient vanishes."""
    x, s = _rand(9, 25)
    y = np.asarray(weighted_gm(x, s, iters=64))
    xn, sn = np.asarray(x, np.float64), np.asarray(s, np.float64)
    dist = np.linalg.norm(xn - y, axis=1)
    sub = ((sn / dist)[:, None] * (xn - y)).sum(0)
    assert np.linalg.norm(sub) < 1e-3


def test_ctma_lam0_is_weighted_mean():
    x, s = _rand(11, 30)
    np.testing.assert_allclose(np.asarray(weighted_ctma(x, s, lam=0.0)),
                               np.asarray(weighted_mean(x, s)), atol=1e-5)


def test_ctma_trims_far_outlier():
    x, s = _rand(10, 20)
    x = x.at[0].set(1e6)  # gross outlier, weight fraction 's[0]/sum' < lam
    s = s.at[0].set(0.5)
    out = weighted_ctma(x, s, lam=0.3)
    assert float(jnp.max(jnp.abs(out))) < 10.0


def test_cwtm_trims_tails():
    x = jnp.concatenate([jnp.zeros((8, 5)), jnp.full((2, 5), 1e9)], axis=0)
    out = weighted_cwtm(x, None, lam=0.25)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-3)


def test_krum_picks_clustered_point():
    x = jnp.concatenate([jnp.zeros((6, 4)), jnp.full((2, 4), 100.0)], axis=0)
    out = krum(x, n_byz=2)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_bucketing_runs_and_bounded():
    x, s = _rand(9, 16)
    out = bucketing(x, s, bucket=3)
    assert out.shape == (16,) and bool(jnp.all(jnp.isfinite(out)))


def test_c_lambda_table():
    """Table 1: base rules (1+λ/(1-2λ))²; CTMA multiplies by 60λ(1+·) -> O(λ)."""
    for lam in (0.1, 0.2, 0.3):
        base = c_lambda("cwmed", lam)
        meta = c_lambda("ctma:cwmed", lam)
        assert base == pytest.approx((1 + lam / (1 - 2 * lam)) ** 2)
        assert meta == pytest.approx(60 * lam * (1 + base))
    # CTMA is O(λ): asymptotically below the O(1) base coefficient
    assert c_lambda("ctma:cwmed", 0.001) < c_lambda("cwmed", 0.001)
    assert c_lambda("ctma:cwmed", 1e-5) / c_lambda("ctma:cwmed", 1e-6) == pytest.approx(10, rel=0.01)


def test_registry_all_specs():
    x, s = _rand(8, 12)
    from repro.agg import AGGREGATOR_SPECS, resolve
    for spec in AGGREGATOR_SPECS:
        out = resolve(spec, lam=0.25)(x, s)
        assert out.shape == (12,)
        assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# exact-tie regression: relative tolerance on the f32 cumsum
# ---------------------------------------------------------------------------

def test_weighted_median_tie_with_large_integer_weights():
    """Regression: integer-valued float weights whose true prefix sum hits
    exactly half the total, but whose float32 cumsum rounds past 2^24 — the
    old atol=0 equality missed the tie and returned a single element instead
    of averaging the two adjacent ones."""
    s = jnp.asarray([7540897.0, 2505645.0, 7567152.0, 5637101.0,
                     7469189.0, 1673657.0, 6360596.0, 7747353.0])
    # first four weights sum to exactly half the total (verified in float64)
    s64 = np.asarray(s, np.float64)
    assert s64[:4].sum() == 0.5 * s64.sum()
    # ... but the f32 cumsum misses exact equality
    cw = np.cumsum(np.asarray(s, np.float32), dtype=np.float32)
    assert not np.any(np.isclose(cw[:-1], 0.5 * cw[-1], rtol=0.0, atol=0.0))

    v = jnp.arange(1.0, 9.0)  # ascending values: tie -> avg of v[3], v[4]
    assert float(weighted_median_1d(v, s)) == pytest.approx(4.5)

    x = jnp.stack([v, v[::-1] * 10.0], axis=1)  # (m, 2): per-column ties
    out = weighted_cwmed(x, s)
    np.testing.assert_allclose(np.asarray(out), [4.5, 45.0], rtol=1e-6)


def test_weighted_median_tie_small_integer_weights_still_exact():
    """Small integer weights (exact cumsum) keep the textbook tie behavior."""
    v = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    s = jnp.asarray([2.0, 2.0, 1.0, 3.0])  # prefix [1,2] hits exactly half
    assert float(weighted_median_1d(v, s)) == pytest.approx(2.5)


def test_weighted_median_no_false_tie_near_half():
    """The relative tolerance must not misfire when a prefix is merely CLOSE
    to half: a gap of ~1e-3 relative is a regular median, not a tie."""
    v = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    s = jnp.asarray([1.0, 0.9995, 1.0, 1.0])
    assert float(weighted_median_1d(v, s)) == 3.0


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

@st.composite
def points_weights(draw, max_m=12, max_d=8):
    m = draw(st.integers(3, max_m))
    d = draw(st.integers(1, max_d))
    x = draw(st.lists(st.lists(st.floats(-100, 100), min_size=d, max_size=d),
                      min_size=m, max_size=m))
    s = draw(st.lists(st.floats(0.0625, 10.0), min_size=m, max_size=m))
    return jnp.asarray(x, jnp.float32), jnp.asarray(s, jnp.float32)


AGGS = {
    "mean": lambda x, s: weighted_mean(x, s),
    "cwmed": lambda x, s: weighted_cwmed(x, s),
    "gm": lambda x, s: weighted_gm(x, s, iters=16),
    "ctma": lambda x, s: weighted_ctma(x, s, lam=0.2),
}


@settings(max_examples=25, deadline=None)
@given(points_weights(), st.sampled_from(sorted(AGGS)))
def test_permutation_invariance(xw, name):
    x, s = xw
    perm = np.random.default_rng(0).permutation(x.shape[0])
    a = AGGS[name](x, s)
    b = AGGS[name](x[perm], s[perm])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(points_weights(), st.sampled_from(sorted(AGGS)))
def test_translation_equivariance(xw, name):
    x, s = xw
    v = jnp.full((x.shape[1],), 7.5)
    a = AGGS[name](x + v, s)
    b = AGGS[name](x, s) + v
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(points_weights())
def test_median_within_honest_range_under_attack(xw):
    """If honest weight mass > 1/2, the weighted median stays inside the honest
    hull per coordinate no matter what the Byzantine rows contain."""
    x, s = xw
    m = x.shape[0]
    n_byz = (m - 1) // 2
    byz = jnp.arange(m) < n_byz
    # Byzantine weight strictly below half:
    s = jnp.where(byz, 0.9 * jnp.sum(s[~byz]) / jnp.maximum(n_byz, 1) / 2, s)
    x_atk = jnp.where(byz[:, None], 1e30, x)
    out = weighted_cwmed(x_atk, s)
    hon = np.asarray(x)[n_byz:]
    assert np.all(np.asarray(out) <= hon.max(0) + 1e-4)
    assert np.all(np.asarray(out) >= hon.min(0) - 1e-4)


@settings(max_examples=20, deadline=None)
@given(points_weights())
def test_weight_splitting_invariance(xw):
    """Splitting one input's weight across two identical rows is a no-op —
    the core soundness property of *weighted* aggregation (Def. 3.1)."""
    x, s = xw
    x2 = jnp.concatenate([x, x[:1]], axis=0)
    s2 = jnp.concatenate([s.at[0].mul(0.5), s[:1] * 0.5])
    for name in ("mean", "cwmed", "ctma"):
        a = AGGS[name](x, s)
        b = AGGS[name](x2, s2)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3,
                                   err_msg=name)
