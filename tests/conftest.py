# NOTE: deliberately NO global XLA_FLAGS here — smoke tests and benchmarks
# must see the single real CPU device; only launch/dryrun.py (and the
# subprocess tests that invoke it) force the 512-placeholder-device platform.
import importlib.util

import numpy as np
import pytest

# The property suites use `hypothesis` (see requirements-dev.txt). In offline
# containers without it, fall back to the vendored minimal shim so the suites
# still run; the real package is preferred whenever it is installed.
if importlib.util.find_spec("hypothesis") is None:
    from repro._hypothesis_fallback import install

    install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
