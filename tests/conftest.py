# NOTE: deliberately NO global XLA_FLAGS here — smoke tests and benchmarks
# must see the single real CPU device; only launch/dryrun.py (and the
# subprocess tests that invoke it) force the 512-placeholder-device platform.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
