"""Data pipeline determinism + checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.data import classification_batches, lm_batches, make_classification_data, worker_batches
from repro.models import ModelConfig, init_lm


def test_classification_deterministic_and_separable():
    d1 = make_classification_data(256, seed=3)
    d2 = make_classification_data(256, seed=3)
    np.testing.assert_array_equal(d1["x"], d2["x"])
    # classes are actually separable: nearest-mean classifier beats chance
    means = np.stack([d1["x"][d1["y"] == c].mean(0) for c in range(10)])
    dists = ((d1["x"][:, None] - means[None]) ** 2).sum((2, 3, 4))
    acc = (dists.argmin(1) == d1["y"]).mean()
    assert acc > 0.5


def test_batch_iterators():
    it = classification_batches(16, seed=0)
    b = next(it)
    assert b["x"].shape == (16, 28, 28, 1) and b["y"].shape == (16,)
    wb = worker_batches(5, 4, seed=0)
    assert wb["x"].shape == (5, 4, 28, 28, 1)


def test_lm_stream_learnable_structure():
    cfg = ModelConfig(vocab=97)
    b = next(lm_batches(cfg, 8, 64, seed=0))
    toks, labels = b["tokens"], b["labels"]
    # labels are the next-token shift and mostly follow the affine rule
    pred = (31 * toks + 17) % 97
    agree = (pred == labels).mean()
    assert agree > 0.8


def test_frontend_batches():
    audio = ModelConfig(frontend="audio", d_model=32, vocab=10)
    b = next(lm_batches(audio, 2, 16))
    assert b["frames"].shape == (2, 16, 32)
    vlm = ModelConfig(frontend="vision", d_model=32, vocab=50, n_patches=4)
    b = next(lm_batches(vlm, 2, 16))
    assert b["patches"].shape == (2, 4, 32) and b["tokens"].shape == (2, 16)


def test_checkpoint_roundtrip(tmp_path):
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv=2, d_ff=64, vocab=32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    save_pytree(params, tmp_path, step=7)
    assert latest_step(tmp_path) == 7
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored = restore_pytree(zeros, tmp_path)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_pytree({"a": jnp.zeros((3,))}, tmp_path, step=1)
    import pytest
    with pytest.raises(ValueError):
        restore_pytree({"a": jnp.zeros((4,))}, tmp_path)
