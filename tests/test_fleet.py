"""repro.fleet: scenario grouping, batched-vs-sequential parity, adaptive
attackers, and the breakdown matrix."""
import math

import numpy as np
import pytest

from repro.fleet import (FleetGroup, Scenario, breakdown_matrix,
                         compile_signature, engine_config, group_scenarios,
                         matrix_rows, matrix_scenarios, resolved_byz_ids,
                         run_scenarios, run_sequential)

QUAD = Scenario(problem="quadratic", attack="sign_flip", agg="ctma:cwmed",
                m=5, byz_frac=0.2, steps=20, batch=4, seed=0)


# ---------------------------------------------------------------------------
# Scenario spec + compile-signature grouping
# ---------------------------------------------------------------------------

def test_traced_knobs_share_a_compile_signature():
    """byz mass, arrival distribution (within sampled kinds), heterogeneity,
    seed and the weighted flag are DATA — same jit serves all of them."""
    variants = [QUAD, QUAD._replace(seed=3), QUAD._replace(alpha=0.3),
                QUAD._replace(byz_frac=0.6), QUAD._replace(weighted=False),
                QUAD._replace(arrival="squared"), QUAD._replace(steps=7)]
    assert len(group_scenarios(variants)) == 1


def test_trace_changing_knobs_split_groups():
    variants = [QUAD, QUAD._replace(attack="little"),
                QUAD._replace(agg="cwmed"), QUAD._replace(m=7),
                QUAD._replace(arrival="round_robin"),
                QUAD._replace(lam=0.5)]
    sigs = {compile_signature(sc) for sc in variants}
    assert len(sigs) == len(variants)


def test_resolved_byz_ids_round_and_clip():
    assert resolved_byz_ids(QUAD._replace(m=9, byz_frac=2 / 9)) == (0, 1)
    assert resolved_byz_ids(QUAD._replace(byz_ids=(3, 4))) == (3, 4)
    # never all-Byzantine: frac 1.0 clips to m-1 ids
    assert len(resolved_byz_ids(QUAD._replace(m=4, byz_frac=1.0))) == 3
    cfg = engine_config(QUAD._replace(m=4, byz_frac=1.0))
    assert len(cfg.byz) == 3


def test_adaptive_scenarios_lower_to_attack_none():
    cfg = engine_config(QUAD._replace(attack="adaptive_scale"))
    assert cfg.attack.name == "none"


def test_fleet_group_rejects_mixed_signatures():
    with pytest.raises(ValueError, match="compile signatures"):
        FleetGroup([QUAD, QUAD._replace(attack="little")])
    grp = FleetGroup([QUAD])
    with pytest.raises(ValueError, match="compile signature"):
        grp.run([QUAD._replace(agg="cwmed")])


# ---------------------------------------------------------------------------
# Batched engine == sequential engine, step for step
# ---------------------------------------------------------------------------

def test_batched_matches_sequential_over_mixed_batch():
    """A mixed group — different seeds, Byzantine masses, heterogeneity and
    the weighted ablation — must reproduce each sequential trajectory
    exactly (same streams, same RNG, one vmapped step)."""
    scs = [QUAD,
           QUAD._replace(seed=11, alpha=0.4),
           QUAD._replace(byz_frac=0.6, weighted=False),
           QUAD._replace(arrival="squared", seed=2)]
    batched = run_scenarios(scs)
    for sc, b in zip(scs, batched):
        s = run_sequential(sc)
        np.testing.assert_allclose(np.asarray(b.state.x),
                                   np.asarray(s.state.x),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(b.state.S),
                                   np.asarray(s.state.S))
        np.testing.assert_allclose(np.asarray(b.state.D),
                                   np.asarray(s.state.D),
                                   rtol=1e-5, atol=1e-6)
        assert int(b.state.t) == sc.steps == int(s.state.t)


def test_batched_parity_with_adaptive_attack():
    sc = QUAD._replace(attack="adaptive_scale", steps=10,
                       attack_params=(("gs_iters", 2), ("n_grid", 3)))
    b, = run_scenarios([sc])
    s = run_sequential(sc)
    np.testing.assert_allclose(np.asarray(b.state.x), np.asarray(s.state.x),
                               rtol=1e-5, atol=1e-6)


def test_mixed_horizons_snapshot_each_scenario():
    scs = [QUAD._replace(steps=6), QUAD._replace(steps=14)]
    r6, r14 = run_scenarios(scs)
    assert int(r6.state.t) == 6 and int(r14.state.t) == 14
    s6 = run_sequential(scs[0])
    np.testing.assert_allclose(np.asarray(r6.state.x),
                               np.asarray(s6.state.x), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Adaptive attackers + the breakdown matrix
# ---------------------------------------------------------------------------

def test_adaptive_attack_beats_its_static_counterpart():
    """The scale-searching attacker tunes z against the resolved ω-CTMA rule
    every step; the static little attack fixes z from mass counts alone. The
    adaptive cell must end at STRICTLY higher loss."""
    base = Scenario(problem="quadratic", agg="ctma:cwmed", m=6,
                    byz_frac=1 / 3, steps=40, batch=4, seed=0)
    static, adaptive = run_scenarios([
        base._replace(attack="little"),
        base._replace(attack="adaptive_scale",
                      attack_params=(("n_grid", 5), ("gs_iters", 3)))])
    assert adaptive.eval["loss"] > static.eval["loss"]


def test_breakdown_matrix_rows_and_bisection():
    scs = matrix_scenarios(problem="quadratic", attacks=("sign_flip",),
                           aggs=("ctma:cwmed",), arrivals=("proportional",),
                           alphas=(math.inf,), m=5, byz_frac=0.2, steps=15,
                           batch=4)
    rows = breakdown_matrix(scs, bisect_steps=10)
    assert len(rows) == 1
    r = rows[0]
    for key in ("cell", "final_loss", "honest_loss", "breakdown_count",
                "breakdown_frac", "agg_us_per_call", "engine_us_per_step"):
        assert key in r
    assert math.isfinite(r["final_loss"]) and math.isfinite(r["honest_loss"])
    assert 1 <= r["breakdown_count"] <= r["m"]
    assert r["breakdown_frac"] == r["breakdown_count"] / r["m"]
    assert r["agg_us_per_call"] > 0
    csv = matrix_rows(rows)
    assert len(csv) == 1 and csv[0].startswith("robust_")
    assert "breakdown_frac=" in csv[0] and "honest=" in csv[0]


def test_matrix_scenarios_grid_size():
    scs = matrix_scenarios(attacks=("a", "b"), aggs=("x",),
                           arrivals=("proportional", "squared"),
                           alphas=(math.inf, 0.3), seeds=(0, 1))
    assert len(scs) == 2 * 1 * 2 * 2 * 2
