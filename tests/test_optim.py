"""μ²-SGD optimizer properties (Levy 2023 / paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import OptConfig, init_opt, opt_query_points, opt_update


def quad_grad(w, key, sigma=0.5):
    wstar = jnp.full_like(w, 3.0)
    return (w - wstar) + sigma * jax.random.normal(key, w.shape)


@pytest.mark.parametrize("name,kw", [
    ("sgd", {}),
    ("momentum", {"beta": 0.9}),
    ("mu2", {"gamma": 0.1, "beta": 0.25}),
    ("mu2", {"gamma": None, "beta": None}),          # theory schedule α_t=t, β=1/t
    ("mu2", {"gamma": 0.1, "beta": 0.25, "implicit_x_prev": True}),
])
def test_converges_on_quadratic(name, kw):
    cfg = OptConfig(name=name, lr=0.05, **kw)
    params = {"w": jnp.zeros((12,))}
    state = init_opt(cfg, params)
    key = jax.random.PRNGKey(0)
    for t in range(300):
        key, k = jax.random.split(key)
        x_t, x_prev = opt_query_points(cfg, state)
        g = {"w": quad_grad(x_t["w"], k)}
        g_tilde = {"w": quad_grad(x_prev["w"], k)} if name == "mu2" else None
        lr_scale = 1.0 / (t + 1) if (name == "mu2" and cfg.gamma is None) else 1.0
        state = opt_update(cfg, state, g, g_tilde, lr_scale=lr_scale)
    final = state.x["w"] if name == "mu2" else state.w["w"]
    assert float(jnp.linalg.norm(final - 3.0)) < 0.6


def test_implicit_x_prev_matches_explicit():
    """The inverted AnyTime recursion must reproduce the stored x_prev exactly."""
    kw = dict(lr=0.03, gamma=0.1, beta=0.25)
    c_exp = OptConfig(name="mu2", **kw)
    c_imp = OptConfig(name="mu2", implicit_x_prev=True, **kw)
    params = {"w": jnp.arange(8.0)}
    s_exp, s_imp = init_opt(c_exp, params), init_opt(c_imp, params)
    key = jax.random.PRNGKey(1)
    for t in range(25):
        key, k = jax.random.split(key)
        xe, xpe = opt_query_points(c_exp, s_exp)
        xi, xpi = opt_query_points(c_imp, s_imp)
        np.testing.assert_allclose(np.asarray(xpi["w"]), np.asarray(xpe["w"]),
                                   rtol=1e-5, atol=1e-5)
        g = {"w": quad_grad(xe["w"], k)}
        gt_e = {"w": quad_grad(xpe["w"], k)}
        gt_i = {"w": quad_grad(xpi["w"], k)}
        s_exp = opt_update(c_exp, s_exp, g, gt_e)
        s_imp = opt_update(c_imp, s_imp, g, gt_i)
    assert s_imp.x_prev is None  # the memory actually is saved


def test_anytime_average_identity():
    """x_T equals the α-weighted average of the iterates w_1..w_T (α_t = t)."""
    cfg = OptConfig(name="mu2", lr=0.01, gamma=None, beta=None)
    params = {"w": jnp.zeros((4,))}
    state = init_opt(cfg, params)
    key = jax.random.PRNGKey(2)
    ws = [np.asarray(state.w["w"])]
    for t in range(30):
        key, k = jax.random.split(key)
        x_t, x_prev = opt_query_points(cfg, state)
        g = {"w": quad_grad(x_t["w"], k)}
        gt = {"w": quad_grad(x_prev["w"], k)}
        state = opt_update(cfg, state, g, gt, lr_scale=1.0 / (t + 1))
        ws.append(np.asarray(state.w["w"]))
    alphas = np.arange(1, len(ws) + 1)
    expect = (alphas[:, None] * np.stack(ws)).sum(0) / alphas.sum()
    np.testing.assert_allclose(np.asarray(state.x["w"]), expect, rtol=1e-4, atol=1e-5)


def test_projection_keeps_ball():
    cfg = OptConfig(name="sgd", lr=10.0, proj_radius=1.0)
    params = {"w": jnp.zeros((6,))}
    state = init_opt(cfg, params)
    for _ in range(5):
        state = opt_update(cfg, state, {"w": jnp.ones((6,))})
        assert float(jnp.linalg.norm(state.w["w"])) <= 1.0 + 1e-5


def test_weight_decay_uniform_across_optimizers():
    """Regression: cfg.weight_decay was applied by server_step (mu2) but
    silently DROPPED by the sgd/momentum branches of opt_update. With zero
    gradients, every optimizer must now shrink w by exactly lr*wd*w."""
    for name, kw in [("sgd", {}), ("momentum", {"beta": 0.9}),
                     ("mu2", {"gamma": 0.1, "beta": 0.25})]:
        cfg = OptConfig(name=name, lr=0.1, weight_decay=0.5, **kw)
        state = init_opt(cfg, {"w": jnp.ones((4,))})
        zeros = {"w": jnp.zeros((4,))}
        state = opt_update(cfg, state, zeros,
                           zeros if name == "mu2" else None)
        np.testing.assert_allclose(np.asarray(state.w["w"]),
                                   np.full((4,), 1.0 - 0.1 * 0.5),
                                   rtol=1e-6, err_msg=name)


def test_weight_decay_default_zero_unchanged():
    """wd=0 keeps the historical sgd/momentum updates bit-for-bit."""
    for name in ("sgd", "momentum"):
        cfg = OptConfig(name=name, lr=0.1)
        state = init_opt(cfg, {"w": jnp.ones((4,))})
        g = {"w": jnp.full((4,), 2.0)}
        state = opt_update(cfg, state, g)
        step = 0.1 * 2.0 * (1.0 if name == "sgd" else (1.0 - 0.9))
        np.testing.assert_allclose(np.asarray(state.w["w"]), 1.0 - step,
                                   rtol=1e-6)
