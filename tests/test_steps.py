"""Train-step integration: standard μ²-SGD and the robust-DP path, including
fault injection — a Byzantine group must not derail training when the robust
aggregator is on, and must visibly hurt with plain mean aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import lm_batches
from repro.dist.steps import (RobustDPConfig, init_train_state, make_robust_train_step,
                              make_train_step)
from repro.models import ModelConfig
from repro.optim import OptConfig

TINY = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_ff=128, vocab=64)


def _run(step_fn, state, data, steps):
    losses = []
    step_fn = jax.jit(step_fn)
    for _ in range(steps):
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in next(data).items()})
        losses.append(float(m["loss"]))
    return losses


def test_standard_step_loss_decreases():
    opt = OptConfig(name="mu2", lr=5e-3, gamma=0.1, beta=0.25)
    state = init_train_state(TINY, opt, jax.random.PRNGKey(0))
    losses = _run(make_train_step(TINY, opt), state, lm_batches(TINY, 8, 32), 60)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, losses[::10]


def test_robust_step_with_byzantine_group():
    opt = OptConfig(name="mu2", lr=5e-3, gamma=0.1, beta=0.25)
    results = {}
    for agg in ("ctma:cwmed", "mean"):
        rcfg = RobustDPConfig(n_groups=4, agg=agg, lam=0.3,
                              byz_groups=(0,), byz_attack="sign_flip")
        state = init_train_state(TINY, opt, jax.random.PRNGKey(0), rcfg)
        losses = _run(make_robust_train_step(TINY, opt, rcfg), state,
                      lm_batches(TINY, 8, 32, seed=1), 60)
        results[agg] = losses
    robust_final = np.mean(results["ctma:cwmed"][-10:])
    mean_final = np.mean(results["mean"][-10:])
    first = np.mean(results["ctma:cwmed"][:10])
    assert robust_final < first - 0.15          # robust training progresses
    assert robust_final <= mean_final + 0.05    # and is no worse than mean


def test_robust_heterogeneous_batch_weights():
    """Remark 3.1: weights ∝ per-group batch sizes."""
    opt = OptConfig(name="mu2", lr=5e-3, gamma=0.1, beta=0.25)
    rcfg = RobustDPConfig(n_groups=4, agg="ctma:cwmed", lam=0.25,
                          weight_mode="batch_size", group_sizes=(1, 2, 3, 2))
    state = init_train_state(TINY, opt, jax.random.PRNGKey(0), rcfg)
    losses = _run(make_robust_train_step(TINY, opt, rcfg), state,
                  lm_batches(TINY, 8, 32, seed=2), 40)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-8:]) < np.mean(losses[:8])


def test_empire_attack_on_groups():
    opt = OptConfig(name="mu2", lr=5e-3, gamma=0.1, beta=0.25)
    rcfg = RobustDPConfig(n_groups=4, agg="ctma:cwmed", lam=0.3,
                          byz_groups=(1,), byz_attack="empire")
    state = init_train_state(TINY, opt, jax.random.PRNGKey(1), rcfg)
    losses = _run(make_robust_train_step(TINY, opt, rcfg), state,
                  lm_batches(TINY, 8, 32, seed=3), 40)
    assert np.isfinite(losses).all()


def test_momentum_and_sgd_steps():
    for name in ("momentum", "sgd"):
        opt = OptConfig(name=name, lr=1e-2)
        state = init_train_state(TINY, opt, jax.random.PRNGKey(0))
        losses = _run(make_train_step(TINY, opt), state, lm_batches(TINY, 8, 32), 30)
        assert np.isfinite(losses).all()


def test_group_sizes_largest_remainder():
    """Regression: skewed ``group_sizes`` vs batch B used to drive the last
    group to zero/negative rows via ``sizes[-1] += B - sum(sizes)`` (an empty
    slice -> NaN group loss). Largest-remainder with a >=1 floor must always
    partition B."""
    from repro.dist.steps import _group_sizes

    # the historical failure: (100,1,1,1) over B=8 gave [7,1,1,-1]
    sizes = _group_sizes(RobustDPConfig(n_groups=4, group_sizes=(100, 1, 1, 1)), 8)
    assert sum(sizes) == 8 and min(sizes) >= 1, sizes
    assert sizes[0] == 5    # bulk goes to the heavy group, floor keeps the rest

    for gs, B in [((100, 1, 1, 1), 8), ((1, 1, 1, 97), 8), ((3, 5), 16),
                  ((7, 7, 7), 10), ((1, 2, 3, 2), 8), ((2, 2, 2, 2), 4)]:
        sizes = _group_sizes(RobustDPConfig(n_groups=len(gs), group_sizes=gs), B)
        assert sum(sizes) == B and min(sizes) >= 1, (gs, B, sizes)
    with pytest.raises(AssertionError):
        _group_sizes(RobustDPConfig(n_groups=4, group_sizes=(1, 1, 1, 1)), 3)
    with pytest.raises(AssertionError):
        # total == B must NOT bypass the floor: a 0-ratio group is rejected
        _group_sizes(RobustDPConfig(n_groups=2, group_sizes=(8, 0)), 8)


def test_robust_step_skewed_group_sizes_finite():
    """The config that used to produce an empty slice now trains with finite
    group losses."""
    opt = OptConfig(name="mu2", lr=5e-3, gamma=0.1, beta=0.25)
    rcfg = RobustDPConfig(n_groups=4, agg="ctma:cwmed", lam=0.25,
                          weight_mode="batch_size", group_sizes=(100, 1, 1, 1))
    state = init_train_state(TINY, opt, jax.random.PRNGKey(0), rcfg)
    losses = _run(make_robust_train_step(TINY, opt, rcfg), state,
                  lm_batches(TINY, 8, 32, seed=4), 5)
    assert np.isfinite(losses).all(), losses


def test_robust_step_weight_decay_applied():
    """sgd/momentum robust steps apply the same decoupled weight decay as
    server_step (they used to drop it silently)."""
    from repro.utils import global_norm

    for name in ("sgd", "momentum"):
        finals = {}
        for wd in (0.0, 0.5):
            opt = OptConfig(name=name, lr=1e-2, weight_decay=wd)
            rcfg = RobustDPConfig(n_groups=2, agg="mean", lam=0.0)
            state = init_train_state(TINY, opt, jax.random.PRNGKey(0), rcfg)
            step = jax.jit(make_robust_train_step(TINY, opt, rcfg))
            data = lm_batches(TINY, 8, 32, seed=5)
            state, _ = step(state, {k: jnp.asarray(v)
                                    for k, v in next(data).items()})
            finals[wd] = float(global_norm(state.opt.w))
        # pre-fix both runs were identical; decoupled decay must shrink w
        assert finals[0.5] < finals[0.0] * 0.999, (name, finals)


def test_smoke_config_with_robust_path():
    cfg = smoke_config("qwen2-moe-a2.7b")
    opt = OptConfig(name="mu2", lr=3e-3, gamma=0.1, beta=0.25)
    rcfg = RobustDPConfig(n_groups=2, agg="cwmed", lam=0.2)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0), rcfg)
    losses = _run(make_robust_train_step(cfg, opt, rcfg), state,
                  lm_batches(cfg, 4, 32), 5)
    assert np.isfinite(losses).all()
