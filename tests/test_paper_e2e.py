"""End-to-end paper reproduction (scaled down): the paper's CNN/MLP classifier
trained by Alg. 2 in an imbalanced asynchronous Byzantine environment reaches
good accuracy with weighted robust aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MLP_SMALL
from repro.core import AsyncByzantineEngine, AttackConfig, EngineConfig
from repro.data import classification_batches, make_classification_data, worker_batches
from repro.models.classifier import (apply_classifier, classifier_accuracy,
                                     classifier_loss, init_classifier)
from repro.optim import OptConfig
from repro.utils import ravel_pytree_fn


def _flat_model(cfg):
    params = init_classifier(jax.random.PRNGKey(0), cfg)
    flat, unravel = ravel_pytree_fn(params)

    def loss_fn(w, batch):
        return classifier_loss(unravel(w), cfg, batch)

    return flat, unravel, loss_fn


@pytest.mark.parametrize("attack,lam_set", [("sign_flip", (7, 8)), ("label_flip", (7, 8))])
def test_async_robust_training_reaches_accuracy(attack, lam_set):
    mcfg = MLP_SMALL
    flat, unravel, loss_fn = _flat_model(mcfg)
    ecfg = EngineConfig(m=9, byz=lam_set, attack=AttackConfig(attack),
                        agg="ctma:cwmed", lam=0.38, arrival="proportional",
                        opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
    eng = AsyncByzantineEngine(ecfg, loss_fn, flat.shape[0])
    kw = dict(image_hw=mcfg.image_hw, channels=mcfg.channels, seed=0, sigma=0.6)
    init = worker_batches(9, 8, **kw)
    st = eng.init(flat, {"x": jnp.asarray(init["x"]), "y": jnp.asarray(init["y"])})
    data = classification_batches(8, **kw)
    for _ in range(300):
        b = next(data)
        st, m = eng.step(st, {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])})
    test = make_classification_data(512, sample_seed=99, **kw)
    acc = float(classifier_accuracy(unravel(st.x), mcfg,
                                    {"x": jnp.asarray(test["x"]), "y": jnp.asarray(test["y"])}))
    assert acc > 0.75, acc


def test_cnn_forward_shapes():
    from repro.configs.paper_cnn import MNIST_LIKE, CIFAR_LIKE
    for cfg in (MNIST_LIKE, CIFAR_LIKE):
        params = init_classifier(jax.random.PRNGKey(0), cfg)
        x = jnp.zeros((4, *cfg.image_hw, cfg.channels))
        logits = apply_classifier(params, cfg, x)
        assert logits.shape == (4, 10)
        g = jax.grad(lambda p: classifier_loss(p, cfg, {"x": x, "y": jnp.zeros(4, jnp.int32)}))(params)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree_util.tree_leaves(g))
