"""Property tests (hypothesis, or the offline fallback shim from conftest):
the little-attack deviation bound and staleness vote masses."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.agg.logits import staleness_weights
from repro.core.attacks import _little_zmax


def _zmax(honest: float, byz: float) -> float:
    return float(_little_zmax(jnp.asarray(float(honest)),
                              jnp.asarray(float(byz))))


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 80), st.integers(2, 40))
def test_little_zmax_nonneg_at_meaningful_byz_mass(n, b):
    """With at least two units of Byzantine mass the supporting-set quantile
    phi = (n - floor(n/2+1))/(n-b) is >= 1/2, so z_max = Phi^{-1}(phi) >= 0:
    the attack never flips to the WRONG side of the honest mean."""
    b = min(b, n // 2)
    assert _zmax(n - b, b) >= 0.0


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 80), st.integers(1, 40))
def test_little_zmax_monotone_in_byz_mass(n, b):
    """At fixed total mass n the quantile's numerator n - floor(n/2+1) does
    not depend on b while the denominator n - b shrinks — more Byzantine
    mass always licenses a LARGER deviation."""
    b = min(b, n // 2)
    assert _zmax(n - b, b) >= _zmax(n - b + 1, b - 1) - 1e-6


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=2, max_size=8),
       st.floats(1e-3, 0.5))
def test_staleness_weights_respect_floor(lags, floor):
    w = np.asarray(staleness_weights(lags, floor=floor))
    assert np.all(w >= floor - 1e-7)
    assert np.all(np.isfinite(w))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=2, max_size=8))
def test_staleness_weights_order_preserving_in_lag(lags):
    """Fresher replicas (smaller lag) never carry LESS vote mass, and equal
    lags carry equal mass."""
    w = np.asarray(staleness_weights(lags))
    lags = np.asarray(lags)
    for i in range(len(lags)):
        for j in range(len(lags)):
            if lags[i] < lags[j]:
                assert w[i] >= w[j]
            if lags[i] == lags[j]:
                assert w[i] == w[j]
