"""Theorem 4.1 validation: with β_t = 1/s_t, an honest worker's corrected
momentum error satisfies E||d_t^(i) - ∇f(x_t^(i))||² ≲ σ̃²/s_t^(i) — the
per-worker variance reduction that makes the weighted framework optimal.

On a quadratic with known gradient we can evaluate the error exactly and
check (a) errors shrink as update counts grow, and (b) fast workers (large
s_i) end with smaller errors than slow workers — the asymmetry that
motivates weighting by s_i in the first place."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AsyncByzantineEngine, EngineConfig
from repro.optim import OptConfig

D = 24
WSTAR = jnp.full((D,), 2.0)


def loss_fn(w, batch):
    """Per-sample curvature noise (sigma_L > 0) + additive gradient noise:
    f(w; z) = 0.5 (1 + 0.5 a_z) ||w - w*||^2 + b_z.w, so E grad f(w) = w - w*.
    With purely additive noise the mu^2 correction is *exact* (Z_t = 0 and the
    errors collapse geometrically); the multiplicative term exercises the Z_t
    martingale that Thm 4.1 actually bounds."""
    a = batch["x"][:, 0]
    b = batch["x"]
    quad = 0.5 * (1.0 + 0.5 * a) * jnp.sum((w - WSTAR) ** 2)
    lin = b @ w
    return jnp.mean(quad + lin) + 0.0 * jnp.sum(batch["y"])


def _worker_errors(steps=1500, seed=0):
    cfg = EngineConfig(m=9, byz=(), agg="mean", lam=0.0, arrival="proportional",
                       opt=OptConfig(name="mu2", lr=0.02, gamma=0.1, beta=None),
                       seed=seed)
    eng = AsyncByzantineEngine(cfg, loss_fn, D)
    rng = np.random.default_rng(seed)
    init = {"x": jnp.asarray(rng.normal(size=(9, 4, D)), jnp.float32),
            "y": jnp.zeros((9, 4), jnp.int32)}
    st = eng.init(jnp.zeros((D,)), init)
    for _ in range(steps):
        b = {"x": jnp.asarray(rng.normal(size=(4, D)), jnp.float32),
             "y": jnp.zeros((4,), jnp.int32)}
        st, _ = eng.step(st, b)
    # exact gradient at each worker's last query point: ∇f(x) = x - w*
    true_g = st.Xq - WSTAR[None, :]
    err = np.asarray(jnp.sum((st.D - true_g) ** 2, axis=1))
    counts = np.asarray(st.S)
    return err, counts


def test_error_decreases_with_update_count():
    errs, counts = [], []
    for seed in (0, 1, 2):
        e, c = _worker_errors(seed=seed)
        errs.append(e)
        counts.append(c)
    err = np.concatenate(errs)
    cnt = np.concatenate(counts)
    # (b) fast vs slow workers: top-third update counts must have smaller
    # mean error than the bottom third (σ̃²/s scaling)
    order = np.argsort(cnt)
    third = len(order) // 3
    slow = err[order[:third]].mean()
    fast = err[order[-third:]].mean()
    assert fast < slow, (fast, slow)
    # (a) errors are bounded by c·σ̃²/s for a modest constant: per-sample
    # gradient variance here is σ²=D/4 per batch of 4 -> σ̃² ≈ 2σ² = 12
    sigma_tilde2 = 2 * (D / 4.0)
    bound = 20.0 * sigma_tilde2 / np.maximum(cnt, 1.0)
    frac_within = np.mean(err <= bound)
    assert frac_within > 0.9, (frac_within, err * cnt / sigma_tilde2)
