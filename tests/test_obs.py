"""repro.obs: jit-safe telemetry, tracing and reports.

The contract under test is the ISSUE's acceptance bar:

- instrumentation is BEHAVIOR-NEUTRAL — greedy token streams (replicated
  serve under attack) and fleet/engine loss trajectories are identical with
  obs on and off;
- everything a run writes validates against the typed registry (metrics
  JSONL) and the Chrome-trace invariants (trace JSON);
- quarantine transitions are structured events carrying the step, the
  replica's score at eviction, and the in-flight request uids;
- the obs README catalog can never drift from the registry (RD203).
"""
import copy
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AsyncByzantineEngine, AttackConfig, EngineConfig
from repro.core.attacks import LogitAttackConfig
from repro.fleet import Scenario, run_scenarios
from repro.models import ModelConfig, init_lm
from repro.obs import (EVENTS, MASS_EDGES, REGISTRY, MetricSink, RunObs,
                       Tracer, histogram, load_jsonl, register,
                       register_event, render_summary, validate_jsonl,
                       validate_trace)
from repro.obs.metrics import TIME_EDGES, bucketize
from repro.optim import OptConfig
from repro.serve import (ReplicatedConfig, ReplicatedServeEngine, ServeConfig,
                         ServeEngine, synth_workload)

V = 64
DENSE = ModelConfig(name="dense", n_layers=2, d_model=32, n_heads=4, n_kv=2,
                    d_ff=64, vocab=V, qkv_bias=True)
SCFG = ServeConfig(n_slots=4, max_len=32, max_prefill_batch=2)


@pytest.fixture(scope="module")
def dense_params():
    return init_lm(jax.random.PRNGKey(0), DENSE)


def _workload(n=6, seed=0):
    return synth_workload(n, V, seed=seed, prompt_lens=(4, 12),
                          gen_lens=(2, 6), rate=0.0)


# ---------------------------------------------------------------------------
# histogram: device collection and its host twin agree
# ---------------------------------------------------------------------------

def test_histogram_matches_host_bucketize():
    vals = np.array([0.0, 0.005, 0.01, 0.03, 0.15, 0.5, 0.95, 2.0])
    dev = np.asarray(histogram(jnp.asarray(vals), MASS_EDGES))
    host = bucketize(vals.tolist(), MASS_EDGES)
    assert dev.shape == (len(MASS_EDGES) + 1,)
    assert float(dev.sum()) == len(vals)
    np.testing.assert_allclose(dev, host)


def test_histogram_edge_is_right_open():
    # a value exactly on an edge lands in the bucket ABOVE it (half-open
    # [lo, hi) buckets) — both on device and on host
    dev = np.asarray(histogram(jnp.asarray([0.1]), MASS_EDGES))
    host = bucketize([0.1], MASS_EDGES)
    idx = list(MASS_EDGES).index(0.1) + 1
    assert dev[idx] == 1.0 and host[idx] == 1.0


def test_histogram_weights_accumulate_mass():
    vals = jnp.asarray([0.05, 0.06, 0.5])
    w = jnp.asarray([1.0, 2.0, 4.0])
    out = np.asarray(histogram(vals, MASS_EDGES, weights=w))
    assert float(out.sum()) == 7.0


def test_histogram_is_jittable():
    f = jax.jit(lambda v: histogram(v, TIME_EDGES))
    out = np.asarray(f(jnp.asarray([1e-5, 2e-3, 0.5])))
    assert out.shape == (len(TIME_EDGES) + 1,) and out.sum() == 3


# ---------------------------------------------------------------------------
# registry + sink: typed, conflict-checked, schema-validated
# ---------------------------------------------------------------------------

def test_register_conflict_raises():
    register("test.obs.gauge", "gauge", unit="x", desc="test")  # idempotent
    register("test.obs.gauge", "gauge", unit="x", desc="test")
    with pytest.raises(ValueError, match="different spec"):
        register("test.obs.gauge", "counter", unit="x", desc="test")
    with pytest.raises(ValueError, match="unknown kind"):
        register("test.obs.bad", "timer")
    with pytest.raises(ValueError, match="bucket_edges"):
        register("test.obs.hist", "histogram")
    register_event("test.obs.event", desc="e")
    with pytest.raises(ValueError, match="different description"):
        register_event("test.obs.event", desc="changed")


def test_sink_rejects_unregistered_names(tmp_path):
    sink = MetricSink(tmp_path / "m.jsonl")
    with pytest.raises(KeyError, match="not registered"):
        sink.log("no.such.metric", 1.0)
    with pytest.raises(KeyError, match="not registered"):
        sink.event("no.such.event")
    sink.close()


def test_sink_jsonl_roundtrip_validates(tmp_path):
    path = tmp_path / "m.jsonl"
    sink = MetricSink(path)
    sink.log("engine.loss", jnp.asarray(1.5), step=1, worker=3)
    sink.log("engine.weight_mass", jnp.asarray([0.25, 0.75]), step=1)
    sink.log("engine.weight_mass_hist",
             histogram(jnp.asarray([0.25, 0.75]), MASS_EDGES), step=1)
    sink.event("serve.quarantine.evict", step=2, replica=1, score=-0.5,
               backoff=3, requests=[0, 1])
    sink.close()
    assert validate_jsonl(path) == []
    rows = load_jsonl(path)
    assert len(rows) == 4
    assert rows[0] == {"metric": "engine.loss", "kind": "gauge",
                       "unit": "nats", "step": 1, "value": 1.5, "worker": 3}
    assert rows[3]["event"] == "serve.quarantine.evict"
    assert rows[3]["requests"] == [0, 1]


def test_validation_catches_schema_breaks(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text(
        '{"metric": "engine.loss", "kind": "gauge", "unit": "nats", '
        '"step": 1, "value": "oops"}\n'
        '{"metric": "nope", "value": 1.0}\n'
        '{"event": "nope.event"}\n'
        '{"metric": "engine.weight_mass_hist", "kind": "histogram", '
        '"unit": "workers", "step": 1, "value": [1, 2]}\n')
    errors = validate_jsonl(path)
    assert len(errors) == 4
    assert any("non-numeric" in e for e in errors)
    assert any("unregistered metric" in e for e in errors)
    assert any("unregistered event" in e for e in errors)
    assert any("buckets" in e for e in errors)


# ---------------------------------------------------------------------------
# tracer: Chrome-trace invariants
# ---------------------------------------------------------------------------

def test_tracer_exports_valid_chrome_trace(tmp_path):
    path = tmp_path / "t.trace.json"
    tr = Tracer(path)
    with tr.span("prefill", n=2):
        pass
    tr.instant("serve.request.admit", uid=0, slot=1)
    tr.counter("serve.queue", depth=3)
    tr.begin_async("request", 0, prompt_len=4)
    tr.end_async("request", 0, gen_tokens=2)
    tr.close()
    assert path.exists()
    assert validate_trace(path) == []
    import json
    doc = json.loads(path.read_text())
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "i", "C", "b", "e", "M"} <= phases


# ---------------------------------------------------------------------------
# RunObs: the one handle the engines take
# ---------------------------------------------------------------------------

def test_runobs_tolerates_missing_halves():
    obs = RunObs()              # no sink, no tracer: everything no-ops
    obs.metric("engine.loss", 1.0)
    obs.event("serve.request.admit", uid=0)
    with obs.span("decode"):
        pass
    obs.counter("serve.queue", depth=1)
    obs.request_begin(0)
    obs.request_end(0)
    obs.close()


# ---------------------------------------------------------------------------
# core engine: obs on == obs off, staleness host-derived
# ---------------------------------------------------------------------------

def _engine_cfg():
    return EngineConfig(m=5, byz=(4,), arrival="proportional",
                        attack=AttackConfig("sign_flip"), agg="ctma:cwmed",
                        lam=0.3,
                        opt=OptConfig(name="mu2", lr=0.02, gamma=0.1,
                                      beta=0.25))


def _loss_fn(w, batch):
    return 0.5 * jnp.mean(jnp.sum((w - batch["x"]) ** 2, -1)) \
        + 0.0 * jnp.sum(batch["y"])


def _drive_engine(collect, obs=None, steps=8, seed=0):
    cfg = _engine_cfg()
    eng = AsyncByzantineEngine(cfg, _loss_fn, 12, collect_metrics=collect)
    rng = np.random.default_rng(seed)
    st = eng.init(jnp.zeros((12,)),
                  {"x": jnp.asarray(rng.normal(size=(cfg.m, 4, 12)),
                                    jnp.float32),
                   "y": jnp.zeros((cfg.m, 4), jnp.int32)})

    def batches():
        while True:
            yield {"x": jnp.asarray(rng.normal(size=(4, 12)), jnp.float32),
                   "y": jnp.zeros((4,), jnp.int32)}

    st, _ = eng.run(st, batches(), steps, obs=obs)
    return np.asarray(st.x)


def test_engine_obs_trajectory_parity(tmp_path):
    ref = _drive_engine(collect=False)
    obs = RunObs(sink=MetricSink(tmp_path / "e.jsonl"))
    instrumented = _drive_engine(collect=True, obs=obs)
    obs.close()
    np.testing.assert_array_equal(ref, instrumented)
    assert validate_jsonl(tmp_path / "e.jsonl") == []
    names = {r.get("metric") for r in load_jsonl(tmp_path / "e.jsonl")}
    assert {"engine.loss", "engine.lambda_emp", "engine.staleness",
            "engine.weight_mass", "engine.weight_mass_hist",
            "engine.byz_mass", "engine.anchor_dist"} <= names


def test_engine_staleness_is_gap_since_previous_arrival(tmp_path):
    obs = RunObs(sink=MetricSink(tmp_path / "s.jsonl"))
    _drive_engine(collect=False, obs=obs, steps=20)
    obs.close()
    rows = [r for r in load_jsonl(tmp_path / "s.jsonl")
            if r.get("metric") == "engine.staleness"]
    assert len(rows) == 20
    last = {}
    for r in rows:
        expect = r["step"] - last.get(r["worker"], r["step"])
        assert r["value"] == expect, r
        last[r["worker"]] = r["step"]
    # the arrival process must actually produce a nonzero staleness
    assert any(r["value"] > 0 for r in rows)


# ---------------------------------------------------------------------------
# fleet: loss trajectory parity, per-scenario rows
# ---------------------------------------------------------------------------

FLEET = [Scenario(problem="quadratic", attack="sign_flip", agg="ctma:cwmed",
                  m=5, byz_frac=0.2, steps=6, batch=4, seed=0, name="a"),
         Scenario(problem="quadratic", attack="sign_flip", agg="ctma:cwmed",
                  m=5, byz_frac=0.2, steps=6, batch=4, seed=3, name="b")]


def test_fleet_obs_trajectory_parity(tmp_path):
    ref = run_scenarios([sc for sc in FLEET])
    obs = RunObs.open(tmp_path, "fleet", compile_events=False)
    instrumented = run_scenarios([sc for sc in FLEET], obs=obs)
    obs.close()
    for a, b in zip(ref, instrumented):
        assert a.eval["loss"] == b.eval["loss"]
        np.testing.assert_array_equal(np.asarray(a.state.x),
                                      np.asarray(b.state.x))
    assert validate_jsonl(tmp_path / "fleet.metrics.jsonl") == []
    rows = load_jsonl(tmp_path / "fleet.metrics.jsonl")
    losses = [r for r in rows if r.get("metric") == "fleet.loss"]
    assert len(losses) == 6                      # one vector row per step
    assert all(len(r["value"]) == 2 for r in losses)   # (S,) per group
    groups = [r for r in rows if r.get("event") == "fleet.group"]
    assert len(groups) == 1 and len(groups[0]["scenarios"]) == 2
    names = {r.get("metric") for r in rows}
    assert {"engine.weight_mass", "engine.byz_mass",
            "engine.anchor_dist"} <= names       # device metrics were on


# ---------------------------------------------------------------------------
# serve: token-stream parity under attack + structured quarantine events
# ---------------------------------------------------------------------------

RCFG = ReplicatedConfig(n_replicas=3, byz=(2,),
                        attack=LogitAttackConfig(name="sign_flip"),
                        quarantine_after=2, readmit_after=3)


def _run_replicated(cfg, params, obs=None):
    eng = ReplicatedServeEngine(cfg, params, SCFG, RCFG, obs=obs)
    return eng.run([copy.deepcopy(r) for r in _workload()])


def test_replicated_obs_token_parity_and_artifacts(tmp_path, dense_params):
    ref = _run_replicated(DENSE, dense_params)
    obs = RunObs.open(tmp_path, "serve")
    rep = _run_replicated(DENSE, dense_params, obs=obs)
    obs.close()

    # byte-identical greedy streams with telemetry on
    assert rep.outputs == ref.outputs

    mpath = tmp_path / "serve.metrics.jsonl"
    tpath = tmp_path / "serve.trace.json"
    assert validate_jsonl(mpath) == []
    assert validate_trace(tpath) == []

    rows = load_jsonl(mpath)
    names = {r.get("metric") for r in rows}
    assert {"serve.queue_depth", "serve.slot_occupancy", "serve.prefill_s",
            "serve.decode_s", "serve.prefill_s_hist", "serve.decode_s_hist",
            "serve.prefill_tokens", "serve.gen_tokens",
            "serve.replica.vote_mass", "serve.replica.score",
            "serve.vote.disagree_mass", "serve.vote.margin"} <= names
    events = {r.get("event") for r in rows}
    assert {"serve.request.admit", "serve.request.finish",
            "serve.quarantine.evict"} <= events

    # vote-mass rows are (R,) vectors; the byz replica's mass hits zero
    masses = [r["value"] for r in rows
              if r.get("metric") == "serve.replica.vote_mass"]
    assert all(len(v) == RCFG.n_replicas for v in masses)
    # the byz replica's eviction zeroes its vote mass in telemetry (the
    # evict/readmit cycle phase at the final tick depends on tick count,
    # so pin the zero anywhere in the stream, not at the end)
    assert any(v[2] == 0.0 for v in masses)

    # the trace is Perfetto-loadable: named tracks + spans + request pairs
    import json
    doc = json.loads(tpath.read_text())
    names_md = {e["args"]["name"] for e in doc["traceEvents"]
                if e["ph"] == "M"}
    assert {"engine", "requests"} <= names_md
    spans = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"decode", "warmup"} <= spans
    # chunked default: there is no separate prefill phase — prefill chunks
    # ride the unified decode span, marked by the chunk_tokens attr
    assert any(e["name"] == "decode" and e["args"].get("chunk_tokens")
               for e in doc["traceEvents"] if e["ph"] == "X")


def test_quarantine_events_carry_step_score_and_requests(tmp_path,
                                                         dense_params):
    obs = RunObs.open(tmp_path, "q", compile_events=False)
    rep = _run_replicated(DENSE, dense_params, obs=obs)
    obs.close()
    assert rep.quarantine_events, "attack scenario must trigger eviction"
    for ev in rep.quarantine_events:          # report-side enrichment
        assert set(ev) >= {"replica", "step", "backoff", "score", "requests"}
        assert ev["replica"] == 2
        assert isinstance(ev["requests"], list)
    evicts = [r for r in load_jsonl(tmp_path / "q.metrics.jsonl")
              if r.get("event") == "serve.quarantine.evict"]
    assert len(evicts) == len(rep.quarantine_events)
    for row, ev in zip(evicts, rep.quarantine_events):
        assert row["step"] == ev["step"] and row["score"] == ev["score"]
        assert row["requests"] == ev["requests"]


def test_single_engine_obs_parity(tmp_path, dense_params):
    ref = ServeEngine(DENSE, dense_params, SCFG).run(
        [copy.deepcopy(r) for r in _workload()])
    obs = RunObs.open(tmp_path, "single", compile_events=False)
    rep = ServeEngine(DENSE, dense_params, SCFG, obs=obs).run(
        [copy.deepcopy(r) for r in _workload()])
    obs.close()
    assert rep.outputs == ref.outputs
    assert validate_jsonl(tmp_path / "single.metrics.jsonl") == []


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

def test_report_renders_run_sections(tmp_path, dense_params):
    obs = RunObs.open(tmp_path, "r", compile_events=False)
    _run_replicated(DENSE, dense_params, obs=obs)
    obs.close()
    rows = load_jsonl(tmp_path / "r.metrics.jsonl")
    import json
    doc = json.loads((tmp_path / "r.trace.json").read_text())
    for fmt in ("text", "md"):
        out = render_summary(rows, trace_doc=doc, fmt=fmt)
        assert "serve.decode_s" in out
        assert "Quarantine timeline" in out
        assert "Per-replica health" in out
    text = render_summary(rows, trace_doc=doc, fmt="text")
    assert "replica 2" in text


def test_obs_cli_validate_and_summarize(tmp_path, dense_params, capsys):
    from repro.launch.obs import main
    obs = RunObs.open(tmp_path, "cli", compile_events=False)
    _run_replicated(DENSE, dense_params, obs=obs)
    obs.close()
    m, t = str(tmp_path / "cli.metrics.jsonl"), str(tmp_path / "cli.trace.json")
    assert main(["--validate", "--metrics", m, "--trace", t]) == 0
    assert "OK" in capsys.readouterr().out
    assert main(["--metrics", m, "--trace", t, "--format", "md"]) == 0
    assert "Per-replica health" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# docs: the registry <-> README catalog contract (RD203)
# ---------------------------------------------------------------------------

def test_every_registered_name_in_obs_readme():
    from pathlib import Path
    readme = (Path(__file__).resolve().parents[1] / "src" / "repro" / "obs"
              / "README.md").read_text()
    missing = [n for n in list(REGISTRY) + list(EVENTS)
               if not n.startswith("test.") and n not in readme]
    assert missing == [], f"obs README catalog is missing {missing}"


def test_rd203_fires_on_undocumented_metric(tmp_path):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
    from lint.docs_rules import check_metric_catalog
    obs_dir = tmp_path / "src" / "repro" / "obs"
    obs_dir.mkdir(parents=True)
    (obs_dir / "metrics.py").write_text(
        'register("x.documented", "gauge")\n'
        'register("x.undocumented", "gauge")\n'
        'register_event("x.event")\n')
    (obs_dir / "README.md").write_text("`x.documented` and `x.event`\n")
    findings = check_metric_catalog(tmp_path)
    assert [f.code for f in findings] == ["RD203"]
    assert "x.undocumented" in findings[0].message
    # documenting it clears the finding
    (obs_dir / "README.md").write_text(
        "`x.documented` `x.undocumented` `x.event`\n")
    assert check_metric_catalog(tmp_path) == []
