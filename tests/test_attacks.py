"""Attack suite tests (Appendix D adaptations + inference-time variants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ATTACKS, LOGIT_ATTACKS, AttackConfig,
                        LogitAttackConfig, byzantine_vector, corrupt_logits,
                        flip_labels, weighted_mean, weighted_std)
from repro.core.attacks import _little_zmax


def _setup(m=8, d=16, seed=0):
    k = jax.random.PRNGKey(seed)
    D = jax.random.normal(k, (m, d))
    honest = jnp.asarray([True] * 6 + [False] * 2)
    s = jnp.arange(1, m + 1, dtype=jnp.float32)
    own = D[-1]
    return D, honest, s, own


def test_sign_flip():
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("sign_flip"), D, honest, s, own)
    np.testing.assert_allclose(np.asarray(out), -np.asarray(own))


def test_label_flip_transform():
    y = jnp.asarray([0, 3, 9])
    np.testing.assert_array_equal(np.asarray(flip_labels(y, 10)), [9, 6, 0])
    # transmission itself is protocol-honest
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("label_flip"), D, honest, s, own)
    np.testing.assert_allclose(np.asarray(out), np.asarray(own))


def test_empire_scaled_negative_weighted_mean():
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("empire", epsilon=0.1), D, honest, s, own)
    hw = s * honest
    mu = weighted_mean(D, hw + 1e-30)
    np.testing.assert_allclose(np.asarray(out), -0.1 * np.asarray(mu), rtol=1e-5)


def test_little_within_spread():
    """ALIE perturbs by z_max weighted std below the weighted mean —
    coordinate-wise, and stays within a few std of the honest mean."""
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("little"), D, honest, s, own)
    hw = s * honest
    mu = np.asarray(weighted_mean(D, hw + 1e-30))
    sd = np.asarray(weighted_std(D, hw + 1e-30))
    dev = np.abs(np.asarray(out) - mu) / (sd + 1e-9)
    assert np.all(dev < 5.0)
    assert np.all(np.asarray(out) <= mu + 1e-6)  # subtractive direction


def test_little_explicit_zmax():
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("little", z_max=1.5), D, honest, s, own)
    hw = s * honest
    mu = np.asarray(weighted_mean(D, hw + 1e-30))
    sd = np.asarray(weighted_std(D, hw + 1e-30))
    np.testing.assert_allclose(np.asarray(out), mu - 1.5 * sd, rtol=1e-4, atol=1e-5)


def test_attack_parity_engine_vs_group_step():
    """The async engine (core.attacks.byzantine_vector) and the synchronous
    group step (dist.steps._apply_byz_attacks) must produce the SAME attack
    vector when handed identical buffers and weights."""
    from repro.dist.steps import RobustDPConfig, _apply_byz_attacks

    m, d, byz_i = 6, 12, 2
    k = jax.random.PRNGKey(7)
    D = jax.random.normal(k, (m, d))
    s = jnp.arange(1.0, m + 1.0)
    honest = jnp.asarray([i != byz_i for i in range(m)])

    for name, acfg in [("empire", AttackConfig("empire", epsilon=0.2)),
                       ("little", AttackConfig("little"))]:
        want = byzantine_vector(acfg, D, honest, s, D[byz_i])
        rcfg = RobustDPConfig(n_groups=m, byz_groups=(byz_i,), byz_attack=name,
                              attack_epsilon=0.2)
        spliced = _apply_byz_attacks(rcfg, {"p": D}, s)["p"]
        np.testing.assert_allclose(np.asarray(spliced[byz_i]),
                                   np.asarray(want), rtol=2e-5, atol=1e-6,
                                   err_msg=name)
        # honest rows pass through untouched
        np.testing.assert_allclose(
            np.asarray(spliced[honest]), np.asarray(D[honest]), rtol=1e-6)


# ---------------------------------------------------------------------------
# full sweep: every attack × both layouts × the m=1 edge case, pinning the
# transmitted update's shape and dtype
# ---------------------------------------------------------------------------

def _pytree_setup(m, d=6, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    tree = {"w": jax.random.normal(k1, (m, d, 2)),
            "b": jax.random.normal(k2, (m, d)).astype(jnp.bfloat16)}
    n_byz = 0 if m == 1 else max(1, m // 4)
    honest = jnp.asarray([True] * (m - n_byz) + [False] * n_byz)
    s = jnp.arange(1, m + 1, dtype=jnp.float32)
    own = jax.tree_util.tree_map(lambda l: l[-1], tree)
    return tree, honest, s, own


@pytest.mark.parametrize("name", ATTACKS)
@pytest.mark.parametrize("layout", ["flat", "pytree"])
@pytest.mark.parametrize("m", [1, 8])
def test_byzantine_vector_shapes_dtypes(name, layout, m):
    """byzantine_vector returns the OWN-UPDATE layout for every attack,
    every buffer layout, down to the degenerate single-worker fleet."""
    if layout == "flat":
        k = jax.random.PRNGKey(0)
        D = jax.random.normal(k, (m, 5))
        honest = jnp.asarray([True] * max(1, m - 1) + [False] * min(1, m - 1))
        s = jnp.arange(1, m + 1, dtype=jnp.float32)
        own = D[-1]
    else:
        D, honest, s, own = _pytree_setup(m)
    out = byzantine_vector(AttackConfig(name), D, honest, s, own)
    o_l, o_t = jax.tree_util.tree_flatten(out)
    w_l, w_t = jax.tree_util.tree_flatten(own)
    assert o_t == w_t
    for o, w in zip(o_l, w_l):
        assert o.shape == w.shape, (name, layout, m)
        assert not np.any(np.isnan(np.asarray(o, np.float32))), (name, layout, m)
    if name in ("none", "label_flip", "sign_flip"):
        # pass-through / negation preserve the input dtype exactly
        for o, w in zip(o_l, w_l):
            assert o.dtype == w.dtype, (name, layout, m)


def test_little_zmax_monotone_in_update_count():
    """z_max grows with the BYZANTINE update mass and shrinks with the honest
    mass: phi = (n-b-s)/(n-b) with s = floor(n/2+1)-b, i.e. roughly
    1/2 + (b-2)/(2h) — the larger the attacker's share of the vote mass, the
    smaller the supporting quorum it must hide inside, so the further it can
    deviate (paper Appendix D, adapted to update counts)."""
    byz = jnp.asarray([4.0, 8.0, 16.0, 24.0])
    z_b = np.asarray(jax.vmap(lambda b: _little_zmax(64.0, b))(byz))
    assert np.all(np.diff(z_b) > 0), z_b
    honest = jnp.asarray([16.0, 32.0, 64.0, 128.0])
    z_h = np.asarray(jax.vmap(lambda h: _little_zmax(h, 8.0))(honest))
    assert np.all(np.diff(z_h) < 0), z_h
    # and it is finite even in the degenerate all-Byzantine corner
    assert np.isfinite(float(_little_zmax(jnp.float32(0.0), jnp.float32(3.0))))


# ---------------------------------------------------------------------------
# inference-time logit attacks (corrupt_logits) — replicated-serving suite
# ---------------------------------------------------------------------------

def _logit_setup(R=4, S=3, V=8, seed=0):
    lg = jax.random.normal(jax.random.PRNGKey(seed), (R, S, V))
    honest = jnp.asarray([True] * (R - 1) + [False])
    s = jnp.arange(1, R + 1, dtype=jnp.float32)
    return lg, honest, s


@pytest.mark.parametrize("name", LOGIT_ATTACKS)
def test_corrupt_logits_honest_rows_untouched(name):
    lg, honest, s = _logit_setup()
    out = corrupt_logits(LogitAttackConfig(name), lg, honest, s,
                         jax.random.PRNGKey(1))
    assert out.shape == lg.shape
    assert out.dtype == jnp.float32
    h = np.asarray(honest)
    np.testing.assert_allclose(np.asarray(out)[h], np.asarray(lg)[h],
                               rtol=1e-6)
    if name != "none":
        # the Byzantine row actually transmits something else
        assert not np.allclose(np.asarray(out)[~h], np.asarray(lg)[~h])


def test_corrupt_logits_transforms():
    lg, honest, s = _logit_setup()
    hw = np.asarray(s * honest)
    mu = np.einsum("r,rsv->sv", hw, np.asarray(lg)) / hw.sum()
    var = np.einsum("r,rsv->sv", hw,
                    np.square(np.asarray(lg) - mu)) / hw.sum()
    byz = np.asarray(~honest)

    out = corrupt_logits(LogitAttackConfig("sign_flip"), lg, honest, s,
                         jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out)[byz], -np.asarray(lg)[byz],
                               rtol=1e-6)
    out = corrupt_logits(LogitAttackConfig("empire", epsilon=0.5), lg, honest,
                         s, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out)[byz][0], -0.5 * mu, rtol=1e-5)
    out = corrupt_logits(LogitAttackConfig("little", z_max=2.0), lg, honest,
                         s, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out)[byz][0],
                               mu - 2.0 * np.sqrt(var), rtol=1e-4, atol=1e-5)
    # corrupt: noise of the configured scale lands on the byz row only
    out = corrupt_logits(LogitAttackConfig("corrupt", noise_scale=100.0), lg,
                         honest, s, jax.random.PRNGKey(1))
    delta = np.asarray(out)[byz] - np.asarray(lg)[byz]
    assert np.abs(delta).max() > 10.0


def test_corrupt_logits_identical_honest_little_degenerates():
    """Honest replicas fresh + identical => honest std 0 => little's transmit
    IS the honest row (documented: it only bites under honest disagreement)."""
    row = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 8))
    lg = jnp.broadcast_to(row, (3, 2, 8))
    honest = jnp.asarray([True, True, False])
    s = jnp.ones((3,))
    out = corrupt_logits(LogitAttackConfig("little"), lg, honest, s,
                         jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out)[2], np.asarray(row)[0],
                               rtol=1e-5, atol=1e-6)
