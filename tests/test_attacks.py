"""Attack suite tests (Appendix D adaptations)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttackConfig, byzantine_vector, flip_labels, weighted_mean, weighted_std


def _setup(m=8, d=16, seed=0):
    k = jax.random.PRNGKey(seed)
    D = jax.random.normal(k, (m, d))
    honest = jnp.asarray([True] * 6 + [False] * 2)
    s = jnp.arange(1, m + 1, dtype=jnp.float32)
    own = D[-1]
    return D, honest, s, own


def test_sign_flip():
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("sign_flip"), D, honest, s, own)
    np.testing.assert_allclose(np.asarray(out), -np.asarray(own))


def test_label_flip_transform():
    y = jnp.asarray([0, 3, 9])
    np.testing.assert_array_equal(np.asarray(flip_labels(y, 10)), [9, 6, 0])
    # transmission itself is protocol-honest
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("label_flip"), D, honest, s, own)
    np.testing.assert_allclose(np.asarray(out), np.asarray(own))


def test_empire_scaled_negative_weighted_mean():
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("empire", epsilon=0.1), D, honest, s, own)
    hw = s * honest
    mu = weighted_mean(D, hw + 1e-30)
    np.testing.assert_allclose(np.asarray(out), -0.1 * np.asarray(mu), rtol=1e-5)


def test_little_within_spread():
    """ALIE perturbs by z_max weighted std below the weighted mean —
    coordinate-wise, and stays within a few std of the honest mean."""
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("little"), D, honest, s, own)
    hw = s * honest
    mu = np.asarray(weighted_mean(D, hw + 1e-30))
    sd = np.asarray(weighted_std(D, hw + 1e-30))
    dev = np.abs(np.asarray(out) - mu) / (sd + 1e-9)
    assert np.all(dev < 5.0)
    assert np.all(np.asarray(out) <= mu + 1e-6)  # subtractive direction


def test_little_explicit_zmax():
    D, honest, s, own = _setup()
    out = byzantine_vector(AttackConfig("little", z_max=1.5), D, honest, s, own)
    hw = s * honest
    mu = np.asarray(weighted_mean(D, hw + 1e-30))
    sd = np.asarray(weighted_std(D, hw + 1e-30))
    np.testing.assert_allclose(np.asarray(out), mu - 1.5 * sd, rtol=1e-4, atol=1e-5)


def test_attack_parity_engine_vs_group_step():
    """The async engine (core.attacks.byzantine_vector) and the synchronous
    group step (dist.steps._apply_byz_attacks) must produce the SAME attack
    vector when handed identical buffers and weights."""
    from repro.dist.steps import RobustDPConfig, _apply_byz_attacks

    m, d, byz_i = 6, 12, 2
    k = jax.random.PRNGKey(7)
    D = jax.random.normal(k, (m, d))
    s = jnp.arange(1.0, m + 1.0)
    honest = jnp.asarray([i != byz_i for i in range(m)])

    for name, acfg in [("empire", AttackConfig("empire", epsilon=0.2)),
                       ("little", AttackConfig("little"))]:
        want = byzantine_vector(acfg, D, honest, s, D[byz_i])
        rcfg = RobustDPConfig(n_groups=m, byz_groups=(byz_i,), byz_attack=name,
                              attack_epsilon=0.2)
        spliced = _apply_byz_attacks(rcfg, {"p": D}, s)["p"]
        np.testing.assert_allclose(np.asarray(spliced[byz_i]),
                                   np.asarray(want), rtol=2e-5, atol=1e-6,
                                   err_msg=name)
        # honest rows pass through untouched
        np.testing.assert_allclose(
            np.asarray(spliced[honest]), np.asarray(D[honest]), rtol=1e-6)
