"""repro.serve: continuous-batching engine correctness.

CPU-fast smoke configs (tiny models, short generations). The load-bearing
properties:

- exact right-padded prefill: the cache a padded slot prefill emits matches
  an unpadded per-request prefill across every decode-capable mixer
  (attention, sliding-window ring, SSM state, RG-LRU, MoE);
- continuous vs static parity: same prompts, greedy decode → token-identical
  outputs regardless of arrival order / slot count / slot assignment;
- slot reuse: a freed slot's stale KV never leaks into the next request;
- sampling: temperature=0 is deterministic argmax; temperature>0 is
  deterministic given a seed and identical across engines / slot layouts;
- chunked prefill: the unified ragged step streaming prompts in chunks
  (any chunk size, any chunk_rows, either cache layout, Pallas or oracle)
  is token-identical to the legacy whole-prompt bucketed trio.
"""
import copy
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, decode_step, init_lm, prefill
from repro.serve import (Request, Scheduler, ServeConfig, ServeEngine,
                         default_buckets, synth_workload)

V = 64
MAXLEN = 32

CFGS = [
    ModelConfig(name="dense", n_layers=2, d_model=32, n_heads=4, n_kv=2,
                d_ff=64, vocab=V, qkv_bias=True),
    ModelConfig(name="swa", n_layers=6, d_model=32, n_heads=4, n_kv=2,
                d_ff=64, vocab=V, window=4, global_every=3),
    ModelConfig(name="ssm", arch_type="ssm", n_layers=2, d_model=32,
                n_heads=1, n_kv=1, d_ff=0, vocab=V, ssm_state=8,
                ssm_head_dim=16, ssm_chunk=4),
    ModelConfig(name="hyb", arch_type="hybrid", n_layers=6, d_model=32,
                n_heads=4, n_kv=1, d_ff=64, vocab=V,
                block_pattern=("rec", "rec", "local"), window=4, lru_width=32),
    # generous capacity: MoE rows are independent only while nothing drops
    ModelConfig(name="moe", arch_type="moe", n_layers=2, d_model=32,
                n_heads=4, n_kv=4, d_ff=64, vocab=V, n_experts=4, top_k=2,
                n_shared=1, d_expert=32, capacity_factor=8.0),
]
DENSE = CFGS[0]


@functools.lru_cache(maxsize=None)
def _params(cfg_name: str):
    cfg = next(c for c in CFGS if c.name == cfg_name)
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _fresh(reqs):
    return [copy.deepcopy(r) for r in reqs]


# ---------------------------------------------------------------------------
# exact right-padded prefill + per-slot decode, across all mixers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_padded_prefill_matches_unpadded(cfg):
    cfg, params = _params(cfg.name)
    dec = jax.jit(functools.partial(decode_step, cfg=cfg))
    key = jax.random.PRNGKey(1)
    lens = jnp.asarray([5, 11, 2], jnp.int32)
    toks = jax.random.randint(key, (3, 16), 0, cfg.vocab)
    # lens-prefill emits logits ONLY at each request's last real position
    pl, cache = prefill(params, cfg, {"tokens": toks}, MAXLEN, lens=lens)
    assert pl.shape == (3, 1, cfg.vocab)
    for b in range(3):
        L = int(lens[b])
        rpl, rcache = prefill(params, cfg, {"tokens": toks[b:b + 1, :L]}, MAXLEN)
        err = float(jnp.max(jnp.abs(pl[b, 0] - rpl[0, -1])))
        assert err < 2e-3, (cfg.name, b, err)
        # three decode steps: padded-batch per-slot pos vs scalar reference
        tok = jnp.argmax(rpl[0, -1]).reshape(1, 1).astype(jnp.int32)
        bc = cache
        btoks = jnp.zeros((3, 1), jnp.int32).at[b].set(tok[0])
        for _ in range(3):
            rlog, rcache = dec(params, cache=rcache, tokens=tok)
            blog, bc = dec(params, cache=bc, tokens=btoks)
            err = float(jnp.max(jnp.abs(blog[b, 0] - rlog[0, 0])))
            assert err < 2e-3, (cfg.name, b, err)
            tok = jnp.argmax(rlog[:, 0], -1)[:, None].astype(jnp.int32)
            btoks = jnp.zeros((3, 1), jnp.int32).at[b].set(tok[0])


# ---------------------------------------------------------------------------
# continuous vs static engine parity (greedy)
# ---------------------------------------------------------------------------

def _workload(n=8, seed=3, gen=(2, 6), prompt=(4, 12)):
    return synth_workload(n, V, seed=seed, prompt_lens=prompt, gen_lens=gen,
                          rate=0.0)


def test_continuous_static_parity_greedy():
    cfg, params = _params("dense")
    reqs = _workload()
    cont = ServeEngine(cfg, params,
                       ServeConfig(n_slots=3, max_len=MAXLEN,
                                   max_prefill_batch=2)).run(_fresh(reqs))
    stat = ServeEngine(cfg, params,
                       ServeConfig(n_slots=len(reqs), max_len=MAXLEN),
                       engine="static").run(_fresh(reqs))
    assert cont.outputs == stat.outputs
    for r in reqs:
        assert len(cont.outputs[r.uid]) == r.max_new_tokens
    assert cont.decode_steps > 0 and cont.gen_tokens > 0


def test_arrival_order_and_slot_count_invariance():
    cfg, params = _params("dense")
    reqs = _workload()
    ref = ServeEngine(cfg, params,
                      ServeConfig(n_slots=4, max_len=MAXLEN,
                                  max_prefill_batch=3)).run(_fresh(reqs))
    # reversed submission order, different slot count / prefill packing
    rev = _fresh(reqs)[::-1]
    out = ServeEngine(cfg, params,
                      ServeConfig(n_slots=2, max_len=MAXLEN,
                                  max_prefill_batch=1)).run(rev)
    assert ref.outputs == out.outputs


def test_static_engine_short_pays_for_long():
    """The static baseline cannot retire slots: with one long request in the
    batch, its decode step count is the long request's generation length."""
    cfg, params = _params("dense")
    reqs = [Request(uid=0, tokens=np.arange(4, dtype=np.int32) % V,
                    max_new_tokens=2),
            Request(uid=1, tokens=np.arange(6, dtype=np.int32) % V,
                    max_new_tokens=12)]
    stat = ServeEngine(cfg, params, ServeConfig(n_slots=2, max_len=MAXLEN),
                       engine="static").run(_fresh(reqs))
    assert stat.decode_steps == 11          # 12 tokens: 1 prefill + 11 decodes
    assert stat.mean_occupancy < 1.0        # the short request idles its slot


# ---------------------------------------------------------------------------
# paged (block-table) cache: dense parity, page admission, page reuse
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_paged_matches_dense_all_archs(cfg):
    """Greedy token streams must be identical between the dense slot cache
    and the block-table paged cache, for every decode-capable mixer (global
    attention pages; local ring / SSM / RG-LRU stay per-slot dense)."""
    cfg, params = _params(cfg.name)
    reqs = _workload(n=6, gen=(2, 5))
    dense = ServeEngine(cfg, params,
                        ServeConfig(n_slots=3, max_len=MAXLEN,
                                    max_prefill_batch=2)).run(_fresh(reqs))
    paged = ServeEngine(cfg, params,
                        ServeConfig(n_slots=3, max_len=MAXLEN,
                                    max_prefill_batch=2, paged=True,
                                    page_size=8)).run(_fresh(reqs))
    assert dense.outputs == paged.outputs
    assert paged.paged and paged.mean_pages_per_req > 0


def test_paged_static_parity_and_page_budget_admission():
    """Static engine under paging; and a pool so tight only one request's
    pages fit at a time — admission must wait for retirements, outputs must
    not change."""
    cfg, params = _params("swa")
    reqs = _workload(n=6, gen=(2, 5))
    ref = ServeEngine(cfg, params,
                      ServeConfig(n_slots=3, max_len=MAXLEN)).run(_fresh(reqs))
    stat = ServeEngine(cfg, params,
                       ServeConfig(n_slots=len(reqs), max_len=MAXLEN,
                                   paged=True, page_size=8),
                       engine="static").run(_fresh(reqs))
    assert ref.outputs == stat.outputs
    # 3 pages of 8 rows: a worst-case request (<= 17 positions) needs all 3
    tight = ServeEngine(cfg, params,
                        ServeConfig(n_slots=3, max_len=MAXLEN, paged=True,
                                    page_size=8, n_pages=3)).run(_fresh(reqs))
    assert ref.outputs == tight.outputs
    assert tight.mean_occupancy < ref.mean_occupancy  # pages, not slots, bind


def test_paged_rejects_request_larger_than_pool():
    cfg, params = _params("dense")
    eng = ServeEngine(cfg, params,
                      ServeConfig(n_slots=2, max_len=MAXLEN, paged=True,
                                  page_size=8, n_pages=2))
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(uid=0, tokens=np.zeros(12, np.int32),
                           max_new_tokens=8))  # 20 positions -> 3 pages > 2


def test_paged_pallas_decode_parity():
    """The per-slot dense flash kernel (local ring) and the paged flash
    kernel (global layers) must reproduce the jnp-oracle engine streams."""
    cfg, params = _params("swa")
    reqs = _workload(n=4, gen=(2, 5))
    ref = ServeEngine(cfg, params,
                      ServeConfig(n_slots=2, max_len=MAXLEN)).run(_fresh(reqs))
    pal = cfg.with_(use_pallas_decode=True)
    for paged in (False, True):
        out = ServeEngine(pal, params,
                          ServeConfig(n_slots=2, max_len=MAXLEN, paged=paged,
                                      page_size=8)).run(_fresh(reqs))
        assert ref.outputs == out.outputs, f"paged={paged}"


# ---------------------------------------------------------------------------
# chunked prefill (unified ragged step) vs whole-prompt legacy trio
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True],
                         ids=["dense_cache", "paged_cache"])
@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_chunked_prefill_token_parity(cfg, paged):
    """Chunked prefill through the unified step must stream token-identical
    to the legacy whole-prompt trio, for every decode-capable mixer (global
    KV scatter, local ring carry, SSM conv+state carry, RG-LRU affine carry,
    MoE) on both cache layouts, across chunk sizes including ONE PAGE (8)
    and a whole-prompt-sized chunk (16 >= every prompt here)."""
    cfg, params = _params(cfg.name)
    reqs = _workload(n=5, gen=(2, 5))
    kw = dict(n_slots=3, max_len=MAXLEN, max_prefill_batch=2, paged=paged,
              page_size=8)
    ref = ServeEngine(cfg, params,
                      ServeConfig(chunked=False, **kw)).run(_fresh(reqs))
    assert not ref.chunked
    for C in (4, 8, 16):
        out = ServeEngine(cfg, params,
                          ServeConfig(chunk_size=C, **kw)).run(_fresh(reqs))
        assert out.chunked and out.chunk_size == C
        assert out.outputs == ref.outputs, (cfg.name, paged, C)
        assert out.ttft_p50_s > 0 and out.ttft_p99_s >= out.ttft_p50_s


def test_chunked_multi_chunk_rows_parity():
    """chunk_rows > 1 (several prompts streaming per tick, round-robin) and
    chunk_size=1 (one token per tick — the degenerate chunk) both keep exact
    token parity."""
    cfg, params = _params("dense")
    reqs = _workload(n=8, gen=(2, 5))
    kw = dict(n_slots=4, max_len=MAXLEN, max_prefill_batch=2)
    ref = ServeEngine(cfg, params,
                      ServeConfig(chunked=False, **kw)).run(_fresh(reqs))
    for C, rows in ((4, 3), (1, 2)):
        out = ServeEngine(cfg, params,
                          ServeConfig(chunk_size=C, chunk_rows=rows, **kw)
                          ).run(_fresh(reqs))
        assert out.outputs == ref.outputs, (C, rows)


def test_chunked_pallas_ragged_decode_parity():
    """use_pallas_decode + paged on the chunked path routes global attention
    through the ragged paged Pallas kernel — streams must match the oracle
    engine exactly."""
    cfg, params = _params("dense")
    reqs = _workload(n=4, gen=(2, 5))
    ref = ServeEngine(cfg, params,
                      ServeConfig(n_slots=2, max_len=MAXLEN)).run(_fresh(reqs))
    pal = cfg.with_(use_pallas_decode=True)
    out = ServeEngine(pal, params,
                      ServeConfig(n_slots=2, max_len=MAXLEN, paged=True,
                                  page_size=8, chunk_size=8)).run(_fresh(reqs))
    assert out.chunked and ref.outputs == out.outputs


# ---------------------------------------------------------------------------
# slot reuse
# ---------------------------------------------------------------------------

def test_slot_reuse_no_stale_kv_leak():
    """Serve through ONE slot (maximal reuse) and compare per-request outputs
    against isolated single-request engines."""
    cfg, params = _params("swa")        # ring buffers are the risky case
    reqs = _workload(n=4, seed=9)
    shared = ServeEngine(cfg, params,
                         ServeConfig(n_slots=1, max_len=MAXLEN,
                                     max_prefill_batch=1)).run(_fresh(reqs))
    for r in reqs:
        solo = ServeEngine(cfg, params,
                           ServeConfig(n_slots=1, max_len=MAXLEN,
                                       max_prefill_batch=1)).run(_fresh([r]))
        assert shared.outputs[r.uid] == solo.outputs[r.uid], r.uid


def test_paged_page_reuse_no_stale_page_leak():
    """One slot + a pool exactly one request wide: every request recycles
    the previous one's physical pages. Validity masking (not overwrite) is
    what protects paged reuse — outputs must match isolated runs."""
    cfg, params = _params("swa")
    reqs = _workload(n=4, seed=9)
    kw = dict(n_slots=1, max_len=MAXLEN, max_prefill_batch=1, paged=True,
              page_size=8, n_pages=3)   # ceil(max positions / 8) pages total
    shared = ServeEngine(cfg, params, ServeConfig(**kw)).run(_fresh(reqs))
    for r in reqs:
        solo = ServeEngine(cfg, params, ServeConfig(**kw)).run(_fresh([r]))
        assert shared.outputs[r.uid] == solo.outputs[r.uid], r.uid


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_temperature0_is_deterministic_argmax():
    cfg, params = _params("dense")
    reqs = _workload(n=4)
    scfg = ServeConfig(n_slots=2, max_len=MAXLEN, temperature=0.0, seed=0)
    a = ServeEngine(cfg, params, scfg).run(_fresh(reqs))
    b = ServeEngine(cfg, params, scfg).run(_fresh(reqs))
    assert a.outputs == b.outputs
    # temperature=0 ignores the seed entirely
    c = ServeEngine(cfg, params,
                    ServeConfig(n_slots=2, max_len=MAXLEN, temperature=0.0,
                                seed=123)).run(_fresh(reqs))
    assert a.outputs == c.outputs


def test_sampling_seeded_and_engine_invariant():
    """temperature>0: deterministic given the seed, identical across engines
    and slot layouts (keys bind to request uid + token index, not slots),
    and different seeds actually change the streams."""
    cfg, params = _params("dense")
    reqs = _workload(n=6, gen=(3, 6))
    kw = dict(max_len=MAXLEN, temperature=0.7, top_k=8, seed=11)
    a = ServeEngine(cfg, params,
                    ServeConfig(n_slots=2, max_prefill_batch=1, **kw)
                    ).run(_fresh(reqs))
    b = ServeEngine(cfg, params, ServeConfig(n_slots=6, **kw),
                    engine="static").run(_fresh(reqs))
    assert a.outputs == b.outputs
    other = ServeEngine(cfg, params,
                        ServeConfig(n_slots=2, max_len=MAXLEN,
                                    temperature=0.7, top_k=8, seed=12)
                        ).run(_fresh(reqs))
    assert other.outputs != a.outputs


def test_top_k_one_is_greedy():
    cfg, params = _params("dense")
    reqs = _workload(n=3)
    greedy = ServeEngine(cfg, params,
                         ServeConfig(n_slots=3, max_len=MAXLEN)
                         ).run(_fresh(reqs))
    k1 = ServeEngine(cfg, params,
                     ServeConfig(n_slots=3, max_len=MAXLEN, temperature=0.5,
                                 top_k=1, seed=4)).run(_fresh(reqs))
    assert greedy.outputs == k1.outputs


# ---------------------------------------------------------------------------
# scheduler / engine plumbing
# ---------------------------------------------------------------------------

def test_scheduler_buckets_and_fcfs():
    sched = Scheduler(buckets=(8, 16, 32), max_prefill_batch=4)
    assert sched.bucket_for(5) == 8 and sched.bucket_for(9) == 16
    with pytest.raises(ValueError):
        sched.bucket_for(33)
    mk = lambda uid, L: Request(uid=uid, tokens=np.zeros(L, np.int32),
                                max_new_tokens=1)
    for r in [mk(0, 6), mk(1, 8), mk(2, 20), mk(3, 4)]:
        sched.submit(r)
    plan = sched.plan_prefill(n_free_slots=4)
    # head bucket is 8; request 2 (bucket 32) blocks the pack, FCFS keeps it
    assert [r.uid for r in plan.requests] == [0, 1]
    assert plan.bucket_len == 8
    plan = sched.plan_prefill(n_free_slots=4)
    assert [r.uid for r in plan.requests] == [2, 3]
    assert plan.bucket_len == 32


def test_default_buckets_cover_and_bound_recompiles():
    bs = default_buckets(100)
    assert bs[-1] >= 100 and len(bs) <= 6


def test_engine_rejects_oversized_requests():
    cfg, params = _params("dense")
    eng = ServeEngine(cfg, params, ServeConfig(n_slots=1, max_len=16))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, tokens=np.zeros(10, np.int32),
                           max_new_tokens=10))


def test_submit_rejects_degenerate_requests():
    """Degenerate requests fail AT SUBMIT, with the uid in the message —
    never later inside a prefill plan mid-serve."""
    cfg, params = _params("dense")
    eng = ServeEngine(cfg, params,
                      ServeConfig(n_slots=2, max_len=MAXLEN, buckets=(8, 16)))
    ok = Request(uid=1, tokens=np.zeros(4, np.int32), max_new_tokens=2)
    eng.submit(ok)                                    # sanity: valid passes
    with pytest.raises(ValueError, match="uid.*max_new_tokens|max_new_tokens"):
        eng.submit(Request(uid=7, tokens=np.zeros(4, np.int32),
                           max_new_tokens=0))
    with pytest.raises(ValueError, match="request 8.*largest prefill bucket"):
        eng.submit(Request(uid=8, tokens=np.zeros(17, np.int32),
                           max_new_tokens=2))
    with pytest.raises(ValueError, match="request 9.*empty prompt"):
        eng.submit(Request(uid=9, tokens=np.zeros(0, np.int32),
                           max_new_tokens=2))
    with pytest.raises(ValueError, match="request 10.*1-D"):
        eng.submit(Request(uid=10, tokens=np.zeros((2, 3), np.int32),
                           max_new_tokens=2))
    # nothing degenerate leaked into the queue
    assert eng.sched.n_waiting == 1
    # run()'s fail-fast pre-check uses the same validation
    with pytest.raises(ValueError, match="request 11"):
        eng.run([Request(uid=11, tokens=np.zeros(4, np.int32),
                         max_new_tokens=-3)], warmup=False)


def test_chunked_submit_rejects_overflow_and_keeps_bucket_shim():
    """The chunked engine has no buckets, so the max_len bound is the
    admission ceiling — prompt + max_new > max_len must fail AT SUBMIT with
    the uid in the message. ``Scheduler.bucket_for`` survives as a
    deprecation shim with its exceeded-bucket error path intact."""
    cfg, params = _params("dense")
    eng = ServeEngine(cfg, params, ServeConfig(n_slots=2, max_len=MAXLEN))
    assert eng.chunked
    eng.submit(Request(uid=1, tokens=np.zeros(20, np.int32),
                       max_new_tokens=12))             # 32 == max_len: fits
    with pytest.raises(ValueError, match="request 3.*exceeds max_len"):
        eng.submit(Request(uid=3, tokens=np.zeros(20, np.int32),
                           max_new_tokens=13))
    assert eng.sched.n_waiting == 1
    # run()'s fail-fast pre-check shares the same validation
    with pytest.raises(ValueError, match="request 4.*exceeds max_len"):
        eng.run([Request(uid=4, tokens=np.zeros(30, np.int32),
                         max_new_tokens=30)], warmup=False)
    # the deprecated shim still pads and still raises past the top bucket
    sched = Scheduler(buckets=(8, 16), max_prefill_batch=2)
    with pytest.warns(DeprecationWarning):
        assert sched.bucket_for(5) == 8
    with pytest.warns(DeprecationWarning), \
            pytest.raises(ValueError, match="largest bucket"):
        sched.bucket_for(17)
    # a bucket-less (chunked) scheduler refuses bucket queries outright
    with pytest.warns(DeprecationWarning), pytest.raises(RuntimeError):
        Scheduler(None).bucket_for(5)


def test_synth_workload_fully_seed_deterministic():
    """Same seed = same requests, independently per draw category: turning
    on arrivals or patches must not shift the prompt/gen streams."""
    a = synth_workload(6, V, seed=5, rate=0.0)
    b = synth_workload(6, V, seed=5, rate=0.0)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
        assert ra.max_new_tokens == rb.max_new_tokens
        assert ra.arrival == rb.arrival
    # arrivals ride a separate stream: rate>0 changes ONLY the arrival times
    c = synth_workload(6, V, seed=5, rate=100.0)
    for ra, rc in zip(a, c):
        np.testing.assert_array_equal(ra.tokens, rc.tokens)
        assert ra.max_new_tokens == rc.max_new_tokens
        assert rc.arrival > 0.0
    # patches ride a separate stream too: prompts/gens/arrivals unchanged
    d = synth_workload(6, V, seed=5, rate=100.0, n_patches=2, d_model=4)
    for rc, rd in zip(c, d):
        np.testing.assert_array_equal(rc.tokens, rd.tokens)
        assert rc.max_new_tokens == rd.max_new_tokens
        assert rc.arrival == rd.arrival
        assert rd.patches.shape == (2, 4)
    # and a different seed actually moves the draws
    e = synth_workload(6, V, seed=6, rate=0.0)
    assert any(not np.array_equal(ra.tokens, re.tokens)
               for ra, re in zip(a, e))


def test_report_timing_split():
    """compile/prefill/decode are reported separately and all non-trivial."""
    cfg, params = _params("dense")
    rep = ServeEngine(cfg, params,
                      ServeConfig(n_slots=2, max_len=MAXLEN)
                      ).run(_fresh(_workload(n=3)))
    assert rep.compile_s > 0 and rep.prefill_s > 0 and rep.decode_s > 0
    assert rep.compile_s > rep.prefill_s  # jit compiles dwarf tiny-model math
    assert rep.decode_tok_s > 0 and rep.combined_tok_s > 0
    assert 0 < rep.mean_occupancy <= 1.0
