"""Per-architecture smoke tests: the REDUCED same-family config runs one
forward + one μ²-SGD train step on CPU, asserting shapes and finiteness.
Decode-capable archs also run one prefill + decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_config, SHAPES, shape_applicable
from repro.data import lm_batches
from repro.dist.steps import init_train_state, make_prefill_step, make_serve_step, make_train_step
from repro.models import forward, init_lm
from repro.optim import OptConfig

B, S = 2, 32


def _batch(cfg):
    return next(lm_batches(cfg, B, S, seed=0))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    assert cfg.n_layers <= 6 and cfg.d_model <= 512 and cfg.n_experts <= 4
    opt_cfg = OptConfig(name="mu2", lr=1e-2, gamma=0.1, beta=0.25)
    state = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg).items()}

    logits, aux = forward(state.opt.w, cfg, batch)
    exp_S = S if cfg.frontend != "vision" else S
    assert logits.shape == (B, exp_S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    step = jax.jit(make_train_step(cfg, opt_cfg))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2.opt.t) == 1
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(state.opt.w),
                        jax.tree_util.tree_leaves(state2.opt.w)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode(arch):
    cfg = smoke_config(arch)
    if not cfg.supports_decode():
        pytest.skip("encoder-only: no decode (documented in DESIGN.md)")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    max_len = S + 4
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg).items() if k != "labels"}
    logits, cache = jax.jit(make_prefill_step(cfg, max_len))(params, batch)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    serve = jax.jit(make_serve_step(cfg))
    for _ in range(3):
        logits, cache = serve(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)


def test_full_configs_match_assignment_table():
    spec = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
                cfg.vocab) == (L, d, H, kv, ff, V), arch


def test_moe_configs():
    q = get_config("qwen2-moe-a2.7b")
    assert (q.n_experts, q.top_k, q.n_shared) == (60, 4, 4)
    k = get_config("kimi-k2-1t-a32b")
    assert (k.n_experts, k.top_k) == (384, 8)


def test_shape_applicability_table():
    expected_skips = {
        "hubert-xlarge": {"decode_32k", "long_500k"},
        "qwen2-moe-a2.7b": {"long_500k"},
        "recurrentgemma-9b": set(),
        "qwen2-1.5b": {"long_500k"},
        "gemma3-4b": set(),
        "kimi-k2-1t-a32b": {"long_500k"},
        "gemma3-27b": set(),
        "internvl2-1b": {"long_500k"},
        "codeqwen1.5-7b": {"long_500k"},
        "mamba2-1.3b": set(),
    }
    for arch, skips in expected_skips.items():
        cfg = get_config(arch)
        got = {s for s in SHAPES if not shape_applicable(cfg, s)[0]}
        assert got == skips, (arch, got)
