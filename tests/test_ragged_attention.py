"""Ragged paged attention: oracle vs dense per-request reference, and the
Pallas kernel (interpret mode) vs the oracle.

Property sweep (via the gated hypothesis shim — tests/conftest.py): arbitrary
``cu_q_lens`` splits of a packed token batch, with q_len=1 decode rows,
multi-token prefill chunks, EMPTY chunks, partial last pages, inter-row
padding gaps and trailing padding, must all agree with a reference that never
sees the packing at all — each request's pages gathered dense, sliced to its
true kv length, and run through plain causal SDPA one request at a time.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import ragged_paged_decode
from repro.kernels.ref import ragged_paged_decode_ref
from repro.models.config import ModelConfig
from repro.models.layers import _sdpa

H, KV, HD = 4, 2, 8
P, PPS = 4, 5                    # page size / pages per row (max kv 20)


def _case(seed: int, n_rows: int, max_q: int = 5):
    """One ragged batch drawn from ``seed``: packed q + pools + table."""
    rng = np.random.default_rng(seed)
    q_lens = rng.integers(0, max_q + 1, n_rows)
    if q_lens.sum() == 0:
        q_lens[rng.integers(0, n_rows)] = 1
    # context AFTER the chunk; rows with q_len=0 may have kv_len=0 too
    kv_lens = np.array([rng.integers(ql, PPS * P + 1) if ql or rng.integers(2)
                        else 0 for ql in q_lens])
    strides = q_lens + rng.integers(0, 3, n_rows)       # inter-row padding
    cu = np.concatenate([[0], np.cumsum(strides)])
    T = int(cu[-1] + rng.integers(0, 3))                # trailing padding
    T = max(T, 1)

    pages_needed = -(-kv_lens // P)
    n_pages = max(int(pages_needed.sum()), 1)
    perm = rng.permutation(n_pages)
    table = np.full((n_rows, PPS), n_pages, np.int32)   # dump everywhere
    nxt = 0
    for r in range(n_rows):
        for j in range(pages_needed[r]):
            table[r, j] = perm[nxt]
            nxt += 1
    q = rng.standard_normal((T, H, HD)).astype(np.float32)
    k_pool = rng.standard_normal((n_pages + 1, P, KV, HD)).astype(np.float32)
    v_pool = rng.standard_normal((n_pages + 1, P, KV, HD)).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(table), jnp.asarray(cu, jnp.int32),
            jnp.asarray(q_lens, jnp.int32), jnp.asarray(kv_lens, jnp.int32))


def _dense_reference(q, k_pool, v_pool, table, cu, q_lens, kv_lens):
    """Per-request dense gather reference: no packing, no dump-row masking —
    each row's pages are gathered dense, SLICED to the true kv length, and
    attended with a plain causal mask at the row's absolute offset."""
    T = q.shape[0]
    cfg = ModelConfig(n_heads=H, n_kv=KV, head_dim=HD)
    out = np.zeros((T, H, HD), np.float32)
    for r in range(table.shape[0]):
        ql, kvl = int(q_lens[r]), int(kv_lens[r])
        if ql == 0:
            continue
        kd = np.asarray(k_pool)[np.asarray(table[r])].reshape(-1, KV, HD)
        vd = np.asarray(v_pool)[np.asarray(table[r])].reshape(-1, KV, HD)
        kd, vd = kd[:kvl], vd[:kvl]                     # true keys only
        qr = q[int(cu[r]):int(cu[r]) + ql]              # (ql, H, hd)
        iq = np.arange(ql)[:, None] + (kvl - ql)
        mask = jnp.asarray(np.arange(kvl)[None, :] <= iq)
        o = _sdpa(cfg, qr[None], jnp.asarray(kd)[None], jnp.asarray(vd)[None],
                  mask[None, None])
        out[int(cu[r]):int(cu[r]) + ql] = \
            np.asarray(o[0], np.float32).reshape(ql, H, HD)
    return out


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_oracle_matches_dense_per_request_reference(seed, n_rows):
    case = _case(seed, n_rows)
    got = np.asarray(ragged_paged_decode_ref(*case))
    want = _dense_reference(*case)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3))
def test_pallas_kernel_matches_oracle(seed, n_rows):
    case = _case(seed, n_rows)
    want = np.asarray(ragged_paged_decode_ref(*case))
    got = np.asarray(ragged_paged_decode(*case, use_pallas=True,
                                         interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def _fixed_case(q_lens, kv_lens, strides=None, trailing=0, seed=7):
    q_lens = np.asarray(q_lens)
    kv_lens = np.asarray(kv_lens)
    rng = np.random.default_rng(seed)
    strides = q_lens if strides is None else np.asarray(strides)
    cu = np.concatenate([[0], np.cumsum(strides)])
    T = int(cu[-1]) + trailing
    pages_needed = -(-kv_lens // P)
    n_pages = max(int(pages_needed.sum()), 1)
    table = np.full((len(q_lens), PPS), n_pages, np.int32)
    nxt = 0
    for r in range(len(q_lens)):
        for j in range(pages_needed[r]):
            table[r, j] = nxt
            nxt += 1
    q = rng.standard_normal((T, H, HD)).astype(np.float32)
    k_pool = rng.standard_normal((n_pages + 1, P, KV, HD)).astype(np.float32)
    v_pool = rng.standard_normal((n_pages + 1, P, KV, HD)).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(table), jnp.asarray(cu, jnp.int32),
            jnp.asarray(q_lens, jnp.int32), jnp.asarray(kv_lens, jnp.int32))


@pytest.mark.parametrize("q_lens,kv_lens,kw", [
    ((1, 1, 1), (5, 13, 1), {}),                 # all-decode, partial pages
    ((3, 0, 2), (9, 0, 7), {}),                  # empty chunk mid-batch
    ((4,), (4,), {"trailing": 3}),               # fresh prefill + trailing pad
    ((2, 1), (18, 20), {"strides": (4, 3)}),     # strided packing, deep ctx
], ids=["all_decode", "empty_chunk", "trailing_pad", "strided"])
def test_edge_cases_oracle_and_kernel(q_lens, kv_lens, kw):
    case = _fixed_case(q_lens, kv_lens, **kw)
    want = _dense_reference(*case)
    oracle = np.asarray(ragged_paged_decode_ref(*case))
    np.testing.assert_allclose(oracle, want, rtol=1e-5, atol=1e-5)
    kern = np.asarray(ragged_paged_decode(*case, interpret=True))
    np.testing.assert_allclose(kern, want, rtol=1e-5, atol=1e-5)
    # padding tokens (inter-row gaps + trailing) come back exactly zero
    claimed = np.zeros(case[0].shape[0], bool)
    cu, ql = np.asarray(case[4]), np.asarray(case[5])
    for r in range(len(ql)):
        claimed[cu[r]:cu[r] + ql[r]] = True
    assert np.all(oracle[~claimed] == 0.0) and np.all(kern[~claimed] == 0.0)
