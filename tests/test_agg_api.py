"""Unified aggregator API (`repro.agg`): spec grammar, cross-backend parity,
layout polymorphism, legacy-factory back-compat, and the pytree-native engine.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import agg
from repro.core import AsyncByzantineEngine, AttackConfig, EngineConfig
from repro.optim import OptConfig

KEY = jax.random.PRNGKey(0)


def _rand(m, d, seed=0):
    k = jax.random.fold_in(KEY, seed)
    k1, k2 = jax.random.split(k)
    x = jax.random.normal(k1, (m, d))
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    return x, s


def _as_tree(x):
    """Split an (m, d) matrix into a nested stacked pytree (d >= 16)."""
    m, d = x.shape
    c = d // 4
    return {"a": x[:, :2 * c].reshape(m, 2, c),
            "b": {"c": x[:, 2 * c:3 * c], "d": x[:, 3 * c:]}}


def _flat_result(tree_out, d):
    leaves = [tree_out["a"].reshape(-1), tree_out["b"]["c"].reshape(-1),
              tree_out["b"]["d"].reshape(-1)]
    out = jnp.concatenate(leaves)
    assert out.shape == (d,)
    return out


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------

def test_parse_grammar():
    sp = agg.parse("ctma:gm@pallas", lam=0.3, iters=16)
    assert (sp.rule, sp.base, sp.backend, sp.lam, sp.iters) == \
        ("ctma", "gm", "pallas", 0.3, 16)
    assert sp.canonical == "ctma:gm@pallas"
    # embedded backend beats the keyword; keyword fills when absent
    assert agg.parse("cwmed@jnp", backend="pallas").backend == "jnp"
    assert agg.parse("cwmed", backend="pallas").backend == "pallas"
    # refine an existing spec
    sp2 = agg.parse(sp, lam=0.1)
    assert sp2.lam == 0.1 and sp2.base == "gm"
    # extras ride along as sorted params
    assert agg.parse("krum", n_byz=2).kwargs == {"n_byz": 2}


def test_parse_rejects_malformed():
    with pytest.raises(KeyError):
        agg.parse("cwmed@cuda")
    with pytest.raises((TypeError, ValueError)):
        agg.parse("")
    with pytest.raises(KeyError):
        agg.resolve("no_such_rule")
    with pytest.raises(ValueError):
        agg.resolve("cwmed:gm")  # cwmed does not compose
    with pytest.raises(KeyError):
        agg.resolve("ctma:no_such_base")


# ---------------------------------------------------------------------------
# cross-backend parity: jnp oracle vs pallas kernels vs stacked pytree path
# ---------------------------------------------------------------------------

CASES = [
    ("random", 9, 64),
    ("m1", 1, 64),          # single worker
    ("equal", 8, 64),       # all-equal weights (exact-tie territory)
]


@pytest.mark.parametrize("spec", agg.AGGREGATOR_SPECS)
@pytest.mark.parametrize("case,m,d", CASES)
def test_cross_backend_parity(spec, case, m, d):
    x, s = _rand(m, d, seed=(sum(map(ord, spec + case)) + m) % 1000)
    if case == "equal":
        s = jnp.full((m,), 2.0)
    want = agg.resolve(spec, lam=0.25, backend="jnp")(x, s)
    got_pallas = agg.resolve(spec, lam=0.25, backend="pallas")(x, s)
    got_stacked = _flat_result(
        agg.resolve(spec, lam=0.25)(_as_tree(x), s), d)
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               atol=2e-4, rtol=2e-4, err_msg=f"{spec} pallas")
    np.testing.assert_allclose(np.asarray(got_stacked), np.asarray(want),
                               atol=2e-4, rtol=2e-4, err_msg=f"{spec} stacked")


def test_single_leaf_rank3_array_takes_stacked_path():
    """A bare (m, a, b) array is a stacked single-leaf tree: the leading axis
    reduces, the trailing shape survives."""
    x, s = _rand(7, 24, seed=5)
    out = agg.resolve("ctma:cwmed", lam=0.25)(x.reshape(7, 4, 6), s)
    assert out.shape == (4, 6)
    want = agg.resolve("ctma:cwmed", lam=0.25, backend="jnp")(x, s)
    np.testing.assert_allclose(np.asarray(out).reshape(-1), np.asarray(want),
                               atol=1e-5)


def test_zeno_rejects_corrupt_rows():
    """The Zeno++-style spec trims rows whose descent score is poisoned."""
    x, s = _rand(9, 32, seed=7)
    x = (x * 0.1 + 1.0).at[7:].set(-50.0)  # two corrupt workers
    out = agg.resolve("zeno", lam=0.3)(x, s)
    assert float(jnp.mean(out)) > 0.5  # honest rows average ≈ +1
    # and the same spec on the stacked layout
    out_t = agg.resolve("zeno", lam=0.3)(_as_tree(x), s)
    np.testing.assert_allclose(np.asarray(_flat_result(out_t, 32)),
                               np.asarray(out), atol=1e-5)


def test_composed_spec_routes_extras_to_base():
    """ctma:krum with n_byz must hand n_byz to the krum anchor, not crash
    weighted_ctma; an un-stackable base (bucketing) falls back to the
    flatten adapter instead of a broken callable."""
    x, s = _rand(8, 32, seed=11)
    tree = _as_tree(x)
    f = agg.resolve("ctma:krum", lam=0.25, n_byz=2)
    np.testing.assert_allclose(np.asarray(_flat_result(f(tree, s), 32)),
                               np.asarray(f(x, s)), atol=1e-4)
    out = agg.resolve("ctma:bucketing", lam=0.25)(tree, s)
    np.testing.assert_allclose(
        np.asarray(_flat_result(out, 32)),
        np.asarray(agg.resolve("ctma:bucketing", lam=0.25, backend="jnp")(x, s)),
        atol=1e-5)


def test_legacy_gm_shim_forwards_eps():
    """Regression: the deprecated make_aggregator must forward rule-specific
    kwargs (weighted_gm's eps) exactly like the old factory did."""
    from repro.core.aggregators import make_aggregator, weighted_gm
    x, s = _rand(6, 16, seed=13)
    with pytest.warns(DeprecationWarning):
        got = make_aggregator("gm", eps=5.0)(x, s)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(weighted_gm(x, s, eps=5.0)), atol=1e-6)
    assert not np.allclose(np.asarray(got), np.asarray(weighted_gm(x, s)))


def test_stacked_krum_no_gram_cancellation():
    """Regression: pairwise distances must be formed by direct differences —
    the float32 Gram identity zeroes small gaps between large-norm rows and
    flips Krum's ranking on clustered honest momenta."""
    from repro.core import krum
    from repro.dist.robust import stacked_krum
    k = jax.random.fold_in(KEY, 17)
    x = jnp.full((12,), 1000.0)[None, :] + 1e-3 * jax.random.normal(k, (6, 12))
    tree = {"a": x[:, :8], "b": x[:, 8:]}
    pick = stacked_krum(tree, n_byz=1)
    got = jnp.concatenate([pick["a"], pick["b"]])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(krum(x, n_byz=1)))


def test_jnp_resolve_does_not_import_kernels():
    """backend='jnp' flat aggregation must not pull in the Pallas kernel
    package or the dist layer (lazy builders)."""
    import pathlib, subprocess, sys
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    code = (f"import sys; sys.path.insert(0, {str(src)!r}); "
            "from repro.agg import resolve; import jax.numpy as jnp; "
            "resolve('ctma:cwmed', lam=0.2, backend='jnp')"
            "(jnp.ones((4, 8)), jnp.ones(4)); "
            "assert 'repro.kernels.ops' not in sys.modules; "
            "assert 'repro.dist.robust' not in sys.modules")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True)
    assert r.returncode == 0, r.stderr


def test_register_custom_rule():
    """The registry is open: a one-line rule becomes a first-class spec."""
    agg.register("byzmax", flat=lambda sp: lambda x, s=None: jnp.max(x, axis=0))
    try:
        x, _ = _rand(5, 8)
        np.testing.assert_allclose(np.asarray(agg.resolve("byzmax")(x)),
                                   np.asarray(jnp.max(x, axis=0)))
    finally:
        agg.rules()  # registry intact
        del agg.registry._RULES["byzmax"]


# ---------------------------------------------------------------------------
# back-compat: legacy factories + EngineConfig backends route through repro.agg
# ---------------------------------------------------------------------------

def test_legacy_factories_deprecated_but_working():
    from repro.core.aggregators import make_aggregator
    from repro.dist.robust import make_stacked_aggregator
    from repro.kernels.ops import make_kernel_aggregator

    x, s = _rand(8, 48, seed=3)
    want = agg.resolve("ctma:cwmed", lam=0.25, backend="jnp")(x, s)
    with pytest.warns(DeprecationWarning):
        old = make_aggregator("ctma:cwmed", lam=0.25)(x, s)
    np.testing.assert_allclose(np.asarray(old), np.asarray(want), atol=1e-6)

    with pytest.warns(DeprecationWarning):
        old_k = make_kernel_aggregator("ctma:cwmed", lam=0.25)(x, s)
    np.testing.assert_allclose(np.asarray(old_k), np.asarray(want), atol=1e-4)

    with pytest.warns(DeprecationWarning):
        old_s = make_stacked_aggregator("ctma:cwmed", lam=0.25)(_as_tree(x), s)
    np.testing.assert_allclose(np.asarray(_flat_result(old_s, 48)),
                               np.asarray(want), atol=1e-5)


D_DIM = 20
WSTAR = jnp.full((D_DIM,), 3.0)


def _quad_loss(w, batch):
    return 0.5 * jnp.mean(jnp.sum((w - WSTAR - batch["x"]) ** 2, -1)) \
        + 0.0 * jnp.sum(batch["y"])


def _drive(cfg, loss_fn, params, steps=60, seed=0):
    eng = AsyncByzantineEngine(cfg, loss_fn)
    rng = np.random.default_rng(seed)
    init = {"x": jnp.asarray(rng.normal(size=(cfg.m, 4, D_DIM)), jnp.float32),
            "y": jnp.zeros((cfg.m, 4), jnp.int32)}
    st = eng.init(params, init)
    for _ in range(steps):
        b = {"x": jnp.asarray(rng.normal(size=(4, D_DIM)), jnp.float32),
             "y": jnp.zeros((4,), jnp.int32)}
        st, m = eng.step(st, b)
    return st, m


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_engine_agg_backend_backcompat(backend):
    """EngineConfig(agg=..., agg_backend=...) keeps working through resolve."""
    cfg = EngineConfig(m=5, byz=(4,), attack=AttackConfig("sign_flip"),
                       agg="ctma:cwmed", lam=0.3, agg_backend=backend,
                       opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
    st, _ = _drive(cfg, _quad_loss, jnp.zeros((D_DIM,)))
    assert bool(jnp.all(jnp.isfinite(st.w)))


def test_engine_spec_string_backend():
    """A backend embedded in the spec string ("...@jnp") is honored."""
    cfg = EngineConfig(m=5, byz=(), agg="ctma:cwmed@jnp", lam=0.2)
    eng = AsyncByzantineEngine(cfg, _quad_loss)
    assert eng.agg_fn.spec.backend == "jnp"
    cfg_bad = cfg._replace(agg="ctma:cwmed", agg_backend="cuda")
    with pytest.raises(KeyError):
        AsyncByzantineEngine(cfg_bad, _quad_loss)


# ---------------------------------------------------------------------------
# pytree-native engine: tree state ≡ flat-vector shim, step for step
# ---------------------------------------------------------------------------

def test_engine_pytree_matches_flat_shim():
    """The same quadratic driven with dict params must track the flat (d,)
    run exactly: identical arrival randomness, stacked aggregation ≡ flat."""
    def tree_loss(p, batch):
        w = jnp.concatenate([p["a"].reshape(-1), p["b"].reshape(-1)])
        return _quad_loss(w, batch)

    cfg = EngineConfig(m=6, byz=(4, 5), attack=AttackConfig("sign_flip"),
                       agg="ctma:cwmed", lam=0.35, agg_backend="jnp",
                       opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
    st_flat, _ = _drive(cfg, _quad_loss, jnp.zeros((D_DIM,)), steps=80)
    params = {"a": jnp.zeros((2, 5)), "b": jnp.zeros((D_DIM - 10,))}
    st_tree, m = _drive(cfg, tree_loss, params, steps=80)

    x_tree = jnp.concatenate([st_tree.x["a"].reshape(-1),
                              st_tree.x["b"].reshape(-1)])
    np.testing.assert_allclose(np.asarray(x_tree), np.asarray(st_flat.x),
                               atol=1e-4, rtol=1e-4)
    # stacked per-worker state: leaves carry the (m, ...) worker axis
    assert st_tree.D["a"].shape == (6, 2, 5)
    assert st_tree.Xq["b"].shape == (6, D_DIM - 10)
    assert st_tree.S.shape == (6,)
    assert bool(jnp.isfinite(m["loss"]))


def test_engine_pytree_converges_under_attack():
    def tree_loss(p, batch):
        w = jnp.concatenate([p["a"].reshape(-1), p["b"].reshape(-1)])
        return _quad_loss(w, batch)

    cfg = EngineConfig(m=9, byz=(7, 8), attack=AttackConfig("little"),
                       agg="ctma:cwmed", lam=0.38, arrival="proportional",
                       opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
    params = {"a": jnp.zeros((2, 5)), "b": jnp.zeros((D_DIM - 10,))}
    st, _ = _drive(cfg, tree_loss, params, steps=400)
    x = jnp.concatenate([st.x["a"].reshape(-1), st.x["b"].reshape(-1)])
    assert float(jnp.linalg.norm(x - WSTAR)) < 0.8
