"""Docs health stays pinned in tier-1 (CI runs the lint driver's ``docs``
group): no broken intra-repo markdown links, no public src/repro module
without a docstring. The checks live in tools/lint/docs_rules.py (RD201 /
RD202); tools/docs_check.py remains as a one-PR back-compat shim whose
old list-of-strings API is pinned here too."""
import importlib.util
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

from lint import docs_rules


def test_no_broken_markdown_links():
    assert docs_rules.check_links() == []


def test_public_modules_have_docstrings():
    assert docs_rules.check_docstrings() == []


def test_docs_group_through_driver():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "lint.py"), "--only", "docs"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_docs_check_shim_keeps_old_api():
    path = ROOT / "tools" / "docs_check.py"
    spec = importlib.util.spec_from_file_location("docs_check", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["docs_check"] = mod
    spec.loader.exec_module(mod)
    assert mod.check_links() == []
    assert mod.check_docstrings() == []
    assert mod.main() == 0
