"""Docs health stays pinned in tier-1 (CI also runs tools/docs_check.py as
its own step): no broken intra-repo markdown links, no public src/repro
module without a docstring."""
import importlib.util
import pathlib
import sys


def _load_docs_check():
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" / "docs_check.py"
    spec = importlib.util.spec_from_file_location("docs_check", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["docs_check"] = mod
    spec.loader.exec_module(mod)
    return mod


def test_no_broken_markdown_links():
    assert _load_docs_check().check_links() == []


def test_public_modules_have_docstrings():
    assert _load_docs_check().check_docstrings() == []
