"""repro.serve.replicated: Byzantine-tolerant replicated decode.

The load-bearing properties:

- honest-fresh parity — with all replicas honest and fresh, the voted
  greedy stream is TOKEN-IDENTICAL to the single-replica ServeEngine,
  across every decode-capable arch and both cache layouts (the vmapped
  replica decode is bitwise-equal per replica, and every robust rule
  returns the common row of an identical stack);
- fault masking — with f < R/2 Byzantine vote mass under every logit
  attack, and with dead / hanging / stale-checkpoint replicas, the voted
  stream still matches the honest one;
- graceful degradation — the Zeno++-style pre-vote gate quarantines a
  persistently divergent replica within ``quarantine_after`` decode steps,
  re-admits it after backoff with a coherent KV cache, and reports
  per-replica health;
- units — staleness_weights maps lag to the paper's update-count masses
  and resolve_logits is exactly the flat rule vmapped over slots.
"""
import copy
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import resolve, resolve_logits, staleness_weights
from repro.core.attacks import LogitAttackConfig
from repro.models import ModelConfig, init_lm
from repro.serve import (ReplicatedConfig, ReplicatedServeEngine, Request,
                         ServeConfig, ServeEngine, stale_params_stack,
                         synth_workload)

V = 64
MAXLEN = 32

CFGS = [
    ModelConfig(name="dense", n_layers=2, d_model=32, n_heads=4, n_kv=2,
                d_ff=64, vocab=V, qkv_bias=True),
    ModelConfig(name="swa", n_layers=6, d_model=32, n_heads=4, n_kv=2,
                d_ff=64, vocab=V, window=4, global_every=3),
    ModelConfig(name="ssm", arch_type="ssm", n_layers=2, d_model=32,
                n_heads=1, n_kv=1, d_ff=0, vocab=V, ssm_state=8,
                ssm_head_dim=16, ssm_chunk=4),
    ModelConfig(name="hyb", arch_type="hybrid", n_layers=6, d_model=32,
                n_heads=4, n_kv=1, d_ff=64, vocab=V,
                block_pattern=("rec", "rec", "local"), window=4, lru_width=32),
    ModelConfig(name="moe", arch_type="moe", n_layers=2, d_model=32,
                n_heads=4, n_kv=4, d_ff=64, vocab=V, n_experts=4, top_k=2,
                n_shared=1, d_expert=32, capacity_factor=8.0),
]
DENSE = CFGS[0]


@functools.lru_cache(maxsize=None)
def _params(cfg_name: str):
    cfg = next(c for c in CFGS if c.name == cfg_name)
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _scfg(**kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", MAXLEN)
    kw.setdefault("max_prefill_batch", 2)
    return ServeConfig(**kw)


def _workload(n=6, seed=0):
    return synth_workload(n, V, seed=seed, prompt_lens=(4, 12),
                          gen_lens=(2, 6), rate=0.0)


def _run(engine_cls, cfg, params, scfg, *args, reqs=None):
    reqs = [copy.deepcopy(r) for r in (reqs or _workload())]
    return engine_cls(cfg, params, scfg, *args).run(reqs)


@functools.lru_cache(maxsize=None)
def _honest(cfg_name: str):
    cfg, params = _params(cfg_name)
    return _run(ServeEngine, cfg, params, _scfg()).outputs


# ---------------------------------------------------------------------------
# honest-fresh parity: voted stream == single-replica stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_honest_fresh_parity_all_archs(cfg):
    cfg, params = _params(cfg.name)
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=2))
    assert rep.outputs == _honest(cfg.name)
    assert all(h["evictions"] == 0 for h in rep.replicas)


def test_honest_fresh_parity_paged():
    cfg, params = _params("dense")
    scfg = _scfg(paged=True, page_size=8)
    single = _run(ServeEngine, cfg, params, scfg)
    rep = _run(ReplicatedServeEngine, cfg, params, scfg,
               ReplicatedConfig(n_replicas=2))
    assert rep.outputs == single.outputs


def test_per_replica_checkpoints_accepted():
    cfg, params = _params("dense")
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=2), reqs=_workload())
    # a list of R per-replica checkpoints is the same as broadcasting one
    rep2 = ReplicatedServeEngine(cfg, [params, params], _scfg(),
                                 ReplicatedConfig(n_replicas=2)
                                 ).run([copy.deepcopy(r) for r in _workload()])
    assert rep.outputs == rep2.outputs


# ---------------------------------------------------------------------------
# fault masking: f < R/2 Byzantine / dead / hanging / stale replicas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attack", ["corrupt", "sign_flip", "little", "empire"])
def test_byzantine_attack_masked(attack):
    cfg, params = _params("dense")
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=3, byz=(2,),
                                attack=LogitAttackConfig(name=attack)))
    assert rep.outputs == _honest("dense"), attack
    assert rep.attack == attack
    # the transmitted corruption is visible in the byz replica's health
    # (except little, which degenerates on identical-fresh honest replicas)
    if attack != "little":
        assert rep.replicas[2]["divergent_tokens"] > 0


def test_dead_and_hanging_replicas_masked():
    cfg, params = _params("dense")
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=3, dead=(1,), dead_after=2,
                                hang=(2,), hang_period=3))
    assert rep.outputs == _honest("dense")
    assert rep.replicas[1]["tokens_missed"] > 0
    assert rep.replicas[2]["tokens_missed"] > 0
    assert rep.replicas[0]["tokens_missed"] == 0


def test_stale_minority_voted_out():
    """Two fresh replicas + one 3-versions-stale replica: the fresh majority
    mass (4+4 vs 1) votes the fresh stream even though the stale replica's
    checkpoint genuinely differs."""
    cfg, params = _params("dense")
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=3, lags=(0, 0, 3)))
    assert rep.outputs == _honest("dense")
    assert [h["weight"] for h in rep.replicas] == [4.0, 4.0, 1.0]


def test_stale_plus_byzantine_combined():
    """The acceptance regime: stale-but-honest heterogeneity AND a Byzantine
    replica at once — the weighted vote still recovers the fresh stream."""
    cfg, params = _params("dense")
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=4, lags=(0, 0, 2, 0), byz=(3,),
                                attack=LogitAttackConfig(name="sign_flip")))
    assert rep.outputs == _honest("dense")


def test_stale_params_stack_shelf():
    cfg, params = _params("dense")
    stack = stale_params_stack(params, [0, 2, 2], jax.random.PRNGKey(1))
    lv = jax.tree_util.tree_leaves(stack)
    pv = jax.tree_util.tree_leaves(params)
    for s, p in zip(lv, pv):
        assert s.shape == (3,) + p.shape
        # lag 0 IS the fresh checkpoint; equal lags = identical checkpoints
        np.testing.assert_array_equal(np.asarray(s[0]), np.asarray(p))
        np.testing.assert_array_equal(np.asarray(s[1]), np.asarray(s[2]))
    assert any(not np.allclose(np.asarray(s[1]), np.asarray(s[0]))
               for s in lv)


def test_chunked_replicated_matches_legacy_under_attack():
    """The replicated engine's default path is the unified chunked step (the
    tests above all run on it); this pins the explicit A/B: chunked and
    legacy-bucketed replicated serving produce identical voted streams, with
    and without f < R/2 Byzantine mass, including mid-decode chunk ticks
    (chunk_size=4 forces several mixed batches per prompt)."""
    cfg, params = _params("dense")
    for rcfg in (ReplicatedConfig(n_replicas=3),
                 ReplicatedConfig(n_replicas=3, byz=(1,),
                                  attack=LogitAttackConfig(name="sign_flip"))):
        legacy = _run(ReplicatedServeEngine, cfg, params,
                      _scfg(chunked=False), rcfg)
        chunked = _run(ReplicatedServeEngine, cfg, params,
                       _scfg(chunk_size=4), rcfg)
        assert not legacy.chunked and chunked.chunked
        assert chunked.outputs == legacy.outputs == _honest("dense")
        assert chunked.ttft_p50_s > 0


# ---------------------------------------------------------------------------
# graceful degradation: quarantine, backoff, re-admission
# ---------------------------------------------------------------------------

def test_quarantine_evicts_within_policy_window():
    cfg, params = _params("dense")
    rcfg = ReplicatedConfig(n_replicas=3, byz=(2,),
                            attack=LogitAttackConfig(name="sign_flip"),
                            quarantine_after=3)
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(), rcfg)
    assert rep.first_quarantine_step == 3
    byz = rep.replicas[2]
    assert byz["quarantined"] or byz["evictions"] >= 1
    assert byz["mean_score"] < rcfg.zeno_threshold
    assert rep.quarantine_events[0]["replica"] == 2
    # honest replicas never tripped the gate
    assert rep.replicas[0]["evictions"] == 0
    assert rep.replicas[1]["evictions"] == 0


def test_readmission_with_backoff_keeps_stream_honest():
    """Short backoff: the Byzantine replica is evicted, re-admitted (with a
    KV cache kept coherent by decoding the voted tokens while quarantined),
    diverges again and is re-evicted with a doubled backoff — while the
    voted stream never leaves the honest trajectory."""
    cfg, params = _params("dense")
    reqs = synth_workload(8, V, seed=1, prompt_lens=(4, 10), gen_lens=(6, 8),
                          rate=0.0)
    scfg = _scfg(n_slots=2)
    honest = _run(ServeEngine, cfg, params, scfg, reqs=reqs).outputs
    rcfg = ReplicatedConfig(n_replicas=3, byz=(2,),
                            attack=LogitAttackConfig(name="sign_flip"),
                            quarantine_after=2, readmit_after=2,
                            backoff_factor=2.0)
    rep = _run(ReplicatedServeEngine, cfg, params, scfg, rcfg, reqs=reqs)
    assert rep.outputs == honest
    byz = rep.replicas[2]
    assert byz["evictions"] >= 2
    assert byz["quarantined_tokens"] > 0
    backoffs = [e["backoff"] for e in rep.quarantine_events]
    assert backoffs[0] == 2 and backoffs[1] == 4


def test_all_faulty_fleet_falls_back_to_base_masses():
    """Every replica dead -> the availability mask would zero all vote mass;
    the engine falls back to the base staleness masses instead of voting
    with nothing (degraded but deterministic)."""
    cfg, params = _params("dense")
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=2, dead=(0, 1), dead_after=0))
    assert rep.outputs == _honest("dense")


# ---------------------------------------------------------------------------
# config validation + report plumbing
# ---------------------------------------------------------------------------

def test_replicated_config_validation():
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicatedConfig(n_replicas=0).validate()
    with pytest.raises(ValueError, match="unknown logit attack"):
        ReplicatedConfig(attack=LogitAttackConfig(name="nope")).validate()
    with pytest.raises(ValueError, match="out of range"):
        ReplicatedConfig(n_replicas=2, byz=(5,)).validate()
    with pytest.raises(ValueError, match="lags"):
        ReplicatedConfig(n_replicas=3, lags=(1,)).validate()
    cfg, params = _params("dense")
    with pytest.raises(ValueError, match="replica params"):
        ReplicatedServeEngine(cfg, [params], _scfg(),
                              ReplicatedConfig(n_replicas=2))


def test_report_carries_replica_health():
    cfg, params = _params("dense")
    rep = _run(ReplicatedServeEngine, cfg, params, _scfg(),
               ReplicatedConfig(n_replicas=2))
    d = rep.as_dict()
    assert d["n_replicas"] == 2 and d["vote"] == "cwmed"
    assert len(d["replicas"]) == 2
    assert {h["role"] for h in d["replicas"]} == {"honest"}
    assert all(h["mean_score"] > 0.99 for h in d["replicas"])


# ---------------------------------------------------------------------------
# units: staleness weights + logit-layout vote
# ---------------------------------------------------------------------------

def test_staleness_weights():
    w = np.asarray(staleness_weights([0, 0, 3]))
    np.testing.assert_allclose(w, [4.0, 4.0, 1.0])
    # fresh fleet -> uniform unit masses
    np.testing.assert_allclose(np.asarray(staleness_weights([0, 0])), [1, 1])
    # explicit reference version + floor for over-stale replicas
    w = np.asarray(staleness_weights([0, 10], latest_version=5.0))
    np.testing.assert_allclose(w, [5.0, 1e-3])


@pytest.mark.parametrize("spec", ["cwmed", "ctma:cwtm", "gm"])
def test_resolve_logits_is_vmapped_flat_rule(spec):
    R, S, Vv = 4, 3, 8
    lg = jax.random.normal(jax.random.PRNGKey(0), (R, S, Vv))
    s = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    vote = resolve_logits(spec, lam=0.25)
    got = np.asarray(vote(lg, s))
    flat = resolve(spec, lam=0.25)
    want = np.stack([np.asarray(flat(lg[:, j], s)) for j in range(S)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert got.shape == (S, Vv)
