"""Stacked-pytree aggregators (the distributed form) must agree leaf-for-leaf
with the flat-vector originals in core.aggregators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (krum, weighted_ctma, weighted_cwmed, weighted_cwtm,
                        weighted_gm, weighted_mean)
from repro.dist.robust import (stacked_cwmed, stacked_ctma, stacked_cwtm,
                               stacked_gm, stacked_krum, stacked_mean)


def _stacked(m=7, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    tree = {
        "a": jax.random.normal(k1, (m, 4, 6)),
        "b": {"c": jax.random.normal(k2, (m, 10)), "d": jax.random.normal(k3, (m, 2, 3, 2))},
    }
    s = jax.random.uniform(jax.random.fold_in(k, 9), (m,), minval=0.2, maxval=2.0)
    return tree, s


def _flatten(tree, m):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)


def _flatten_result(res):
    leaves = jax.tree_util.tree_leaves(res)
    return jnp.concatenate([l.reshape(-1) for l in leaves])


@pytest.mark.parametrize("stacked_fn,flat_fn,kw", [
    (stacked_mean, weighted_mean, {}),
    (stacked_cwmed, weighted_cwmed, {}),
    (stacked_gm, weighted_gm, {"iters": 8}),
    (stacked_cwtm, weighted_cwtm, {"lam": 0.2}),
    (stacked_krum, krum, {"n_byz": 2}),
])
def test_stacked_matches_flat(stacked_fn, flat_fn, kw):
    tree, s = _stacked()
    m = s.shape[0]
    got = _flatten_result(stacked_fn(tree, s, **kw) if kw else stacked_fn(tree, s))
    want = flat_fn(_flatten(tree, m), s, **kw) if kw else flat_fn(_flatten(tree, m), s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@pytest.mark.parametrize("lam", [0.15, 0.35])
def test_stacked_ctma_matches_flat(lam):
    tree, s = _stacked(seed=3)
    m = s.shape[0]
    got = _flatten_result(stacked_ctma(tree, s, lam=lam))
    want = weighted_ctma(_flatten(tree, m), s, lam=lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_stacked_ctma_rejects_corrupt_group():
    tree, s = _stacked(seed=5)
    corrupt = jax.tree_util.tree_map(
        lambda x: x.at[0].set(jnp.where(jnp.ones_like(x[0]) > 0, 1e8, x[0])), tree)
    out = stacked_ctma(corrupt, s, lam=0.3)
    assert float(jnp.max(jnp.abs(_flatten_result(out)))) < 100.0


def test_registry():
    from repro.agg import resolve
    tree, s = _stacked()
    for spec in ("mean", "cwmed", "gm", "cwtm", "krum", "zeno",
                 "ctma:cwmed", "ctma:gm", "bucketing:cwmed"):
        out = resolve(spec, lam=0.25)(tree, s)
        assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)
