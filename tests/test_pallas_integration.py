"""Kernel-in-model integration: the opt-in Pallas paths must reproduce the
pure-jnp model outputs (decode flash attention; SSD forward)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, decode_step, forward, init_lm, prefill


def test_flash_decode_in_model():
    base = ModelConfig(name="pal", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                       d_ff=128, vocab=64, window=128, global_every=2)
    pal = base.with_(use_pallas_decode=True)
    params = init_lm(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 64)
    _, cache_a = prefill(params, base, {"tokens": toks[:, :8]}, max_len=128)
    _, cache_b = prefill(params, pal, {"tokens": toks[:, :8]}, max_len=128)
    for t in range(8, 12):
        la, cache_a = decode_step(params, base, cache_a, toks[:, t:t + 1])
        lb, cache_b = decode_step(params, pal, cache_b, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-4)


def test_ssd_kernel_in_model():
    base = ModelConfig(name="ssmpal", arch_type="ssm", n_layers=2, d_model=64,
                       n_heads=1, n_kv=1, d_ff=0, vocab=64, ssm_state=16,
                       ssm_head_dim=16, ssm_chunk=8)
    pal = base.with_(use_pallas_ssm=True)
    params = init_lm(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64)
    la, _ = forward(params, base, {"tokens": toks})
    lb, _ = forward(params, pal, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-4)
