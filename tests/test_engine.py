"""Asynchronous Byzantine engine (Alg. 2) integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AsyncByzantineEngine, AttackConfig, EngineConfig,
                        arrival_probs, expected_lambda)
from repro.optim import OptConfig

D_DIM = 20
WSTAR = jnp.full((D_DIM,), 3.0)


def loss_fn(w, batch):
    return 0.5 * jnp.mean(jnp.sum((w - WSTAR - batch["x"]) ** 2, -1)) \
        + 0.0 * jnp.sum(batch["y"])


def _batch(rng, b=4):
    return {"x": jnp.asarray(rng.normal(size=(b, D_DIM)), jnp.float32),
            "y": jnp.zeros((b,), jnp.int32)}


def _init_batches(rng, m, b=4):
    return {"x": jnp.asarray(rng.normal(size=(m, b, D_DIM)), jnp.float32),
            "y": jnp.zeros((m, b), jnp.int32)}


def _run(cfg, steps=400, seed=0):
    eng = AsyncByzantineEngine(cfg, loss_fn, D_DIM)
    rng = np.random.default_rng(seed)
    st = eng.init(jnp.zeros((D_DIM,)), _init_batches(rng, cfg.m))
    for _ in range(steps):
        st, m = eng.step(st, _batch(rng))
    return st, m


def test_arrival_distributions():
    for mode, expect in [("proportional", np.arange(1, 10) / 45),
                         ("squared", np.arange(1, 10) ** 2 / 285),
                         ("uniform", np.full(9, 1 / 9))]:
        p = arrival_probs(EngineConfig(m=9, byz=(), arrival=mode))
        np.testing.assert_allclose(p, expect, rtol=1e-5)


def test_expected_lambda_matches_empirical():
    cfg = EngineConfig(m=9, byz=(7, 8), arrival="proportional",
                       attack=AttackConfig("sign_flip"), agg="cwmed", lam=0.4,
                       opt=OptConfig(name="mu2", lr=0.02, gamma=0.1, beta=0.25))
    st, m = _run(cfg, steps=600)
    lam_exp = expected_lambda(cfg)
    assert lam_exp < 0.5
    assert abs(float(m["lambda_emp"]) - lam_exp) < 0.07


def test_round_robin_visits_all_workers():
    cfg = EngineConfig(m=6, byz=(), arrival="round_robin", agg="mean", lam=0.0,
                       opt=OptConfig(name="mu2", lr=0.02, gamma=0.1, beta=0.25))
    eng = AsyncByzantineEngine(cfg, loss_fn, D_DIM)
    rng = np.random.default_rng(0)
    st = eng.init(jnp.zeros((D_DIM,)), _init_batches(rng, 6))
    for _ in range(12):
        st, _ = eng.step(st, _batch(rng))
    np.testing.assert_array_equal(np.asarray(st.S), np.full(6, 2.0))


@pytest.mark.parametrize("attack,agg", [
    ("sign_flip", "ctma:cwmed"),
    ("label_flip", "ctma:gm"),
    ("little", "ctma:cwmed"),
    ("empire", "gm"),
])
def test_converges_under_attack(attack, agg):
    cfg = EngineConfig(m=9, byz=(7, 8), attack=AttackConfig(attack), agg=agg,
                       lam=0.38, arrival="proportional",
                       opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
    st, _ = _run(cfg, steps=500)
    assert float(jnp.linalg.norm(st.x - WSTAR)) < 0.8


def test_weighted_beats_unweighted_under_imbalance():
    """Fig. 2/5: with arrivals ∝ id² and fast honest workers, weighting by
    update counts beats uniform weights."""
    errs = {}
    for weighted in (True, False):
        cfg = EngineConfig(m=9, byz=(0, 1, 2), attack=AttackConfig("sign_flip"),
                           agg="cwmed", lam=0.2, arrival="squared",
                           opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
        eng = AsyncByzantineEngine(cfg, loss_fn, D_DIM)
        if not weighted:
            inner = eng.agg_fn
            eng.agg_fn = lambda D, S: inner(D, jnp.ones_like(S))
            eng._step = jax.jit(eng._step_impl, donate_argnums=(0,))
        rng = np.random.default_rng(1)
        st = eng.init(jnp.zeros((D_DIM,)), _init_batches(rng, 9))
        for _ in range(500):
            st, _ = eng.step(st, _batch(rng))
        errs[weighted] = float(jnp.linalg.norm(st.x - WSTAR))
    assert errs[True] <= errs[False] + 0.05, errs


def test_sgd_and_momentum_modes_run():
    for opt in (OptConfig(name="sgd", lr=0.02), OptConfig(name="momentum", lr=0.02, beta=0.9)):
        cfg = EngineConfig(m=5, byz=(4,), attack=AttackConfig("sign_flip"),
                           agg="cwmed", lam=0.3, opt=opt)
        st, m = _run(cfg, steps=200)
        assert bool(jnp.all(jnp.isfinite(st.w)))


def test_omniscient_attack_uses_post_increment_weights():
    """Regression (stale weights): little/empire must see the POST-increment
    update counts, like the synchronous group step. m=3 round-robin: at the
    Byzantine worker's first arrival the counts are [1,1,1] -> n=3 odd ->
    z_max = Phi^-1(0.5) = 0, so its transmission is EXACTLY the weighted
    honest mean. The pre-fix code used the stale [1,1,0] masses -> n=2 even
    -> phi clipped to 1e-4 -> z ~ -3.72, a huge deviation."""
    cfg = EngineConfig(m=3, byz=(2,), arrival="round_robin",
                       attack=AttackConfig("little"), agg="mean", lam=0.0,
                       opt=OptConfig(name="sgd", lr=1e-3))
    eng = AsyncByzantineEngine(cfg, loss_fn, D_DIM)
    rng = np.random.default_rng(3)
    st = eng.init(jnp.zeros((D_DIM,)), _init_batches(rng, cfg.m))
    st, _ = eng.step(st, _batch(rng))           # worker 0 (honest)
    st, _ = eng.step(st, _batch(rng))           # worker 1 (honest)
    honest_rows = np.asarray(st.D[:2]).copy()   # buffers the attacker sees
    st, m = eng.step(st, _batch(rng))           # worker 2 (Byzantine, little)
    assert bool(m["is_byz"])
    mu = honest_rows.mean(axis=0)               # equal post-counts [1,1]
    np.testing.assert_allclose(np.asarray(st.D[2]), mu, rtol=1e-5, atol=1e-6)


def test_little_attack_zmax_tracks_updated_masses():
    """After k full rounds the little z_max must be derived from the masses
    INCLUDING the arriving Byzantine worker's new count."""
    from repro.core.attacks import _little_zmax

    cfg = EngineConfig(m=3, byz=(2,), arrival="round_robin",
                       attack=AttackConfig("little"), agg="mean", lam=0.0,
                       opt=OptConfig(name="sgd", lr=1e-3))
    eng = AsyncByzantineEngine(cfg, loss_fn, D_DIM)
    rng = np.random.default_rng(4)
    st = eng.init(jnp.zeros((D_DIM,)), _init_batches(rng, cfg.m))
    for _ in range(8):                          # rounds 0-1 + workers 0,1 of round 2
        st, _ = eng.step(st, _batch(rng))
    D_before = np.asarray(st.D).copy()
    st, m = eng.step(st, _batch(rng))           # byz arrival: counts -> [3,3,3]
    assert bool(m["is_byz"])
    hw = np.asarray([3.0, 3.0, 0.0])
    mu = (hw[:, None] * D_before).sum(0) / hw.sum()
    var = (hw[:, None] * (D_before - mu) ** 2).sum(0) / hw.sum()
    z = float(_little_zmax(jnp.asarray(6.0), jnp.asarray(3.0)))   # post masses
    expect = mu - z * np.sqrt(np.maximum(var, 0.0))
    np.testing.assert_allclose(np.asarray(st.D[2]), expect, rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# EngineConfig construction-time validation
# ---------------------------------------------------------------------------

def _cfg(m, byz):
    return EngineConfig(m=m, byz=byz, attack=AttackConfig("sign_flip"),
                        agg="mean", lam=0.0,
                        opt=OptConfig(name="sgd", lr=1e-3))


def test_validate_rejects_out_of_range_byz_ids():
    with pytest.raises(ValueError, match="out of range"):
        _cfg(4, (0, 4)).validate()
    with pytest.raises(ValueError, match="out of range"):
        _cfg(4, (-1,)).validate()


def test_validate_rejects_duplicate_byz_ids():
    with pytest.raises(ValueError, match="duplicate"):
        _cfg(5, (1, 3, 3)).validate()


def test_validate_rejects_all_byzantine_fleet():
    with pytest.raises(ValueError, match="honest"):
        _cfg(3, (0, 1, 2)).validate()


def test_validate_rejects_nonpositive_m():
    with pytest.raises(ValueError, match="m must be"):
        _cfg(0, ()).validate()


def test_validate_accepts_valid_config_and_returns_self():
    cfg = _cfg(5, (3, 4))
    assert cfg.validate() is cfg


def test_engine_constructor_validates():
    """The engine itself must refuse a degenerate config, not just
    validate() callers."""
    with pytest.raises(ValueError, match="out of range"):
        AsyncByzantineEngine(_cfg(4, (7,)), loss_fn, D_DIM)
