"""Sharded (shard_map) MoE dispatch must match the dense dispatch bit-for-bit
on a real multi-device mesh — forward and gradients."""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.models import ModelConfig, init_lm, forward
from repro.models.lm import lm_loss
from repro.dist.context import mesh_context

cfg_d = ModelConfig(name="moe", arch_type="moe", n_layers=2, d_model=64, n_heads=4,
                    n_kv=4, d_ff=128, vocab=64, n_experts=4, top_k=2, n_shared=1,
                    d_expert=64, capacity_factor=8.0, moe_dispatch="dense")
cfg_s = cfg_d.with_(moe_dispatch="sharded")
key = jax.random.PRNGKey(0)
params = init_lm(key, cfg_d)
toks = jax.random.randint(key, (4, 16), 0, 64)
mesh = jax.make_mesh((4, 2), ("data", "model"))
ref, _ = forward(params, cfg_d, {"tokens": toks})
with mesh, mesh_context(mesh):
    out, _ = jax.jit(lambda p, t: forward(p, cfg_s, {"tokens": t}))(params, toks)
assert float(jnp.max(jnp.abs(out - ref))) < 2e-4
g_ref = jax.grad(lambda p: lm_loss(p, cfg_d, {"tokens": toks, "labels": toks}))(params)
with mesh, mesh_context(mesh):
    g_s = jax.jit(jax.grad(lambda p: lm_loss(p, cfg_s, {"tokens": toks, "labels": toks})))(params)
errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_s))]
assert max(errs) < 5e-4, max(errs)
print("SHARDED_MOE_MATCH")
"""


def test_sharded_moe_matches_dense_on_mesh():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDED_MOE_MATCH" in r.stdout
