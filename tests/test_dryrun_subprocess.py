"""Integration: the dry-run machinery end-to-end in a subprocess with a small
placeholder-device mesh (8 devices, 2x2 / 2x2x2). Exercises lowering, SPMD
compile, cost/memory analysis and the collective-bytes parser for one arch per
step kind. The full 512-device production sweep is run by
`python -m repro.launch.dryrun --all` (see EXPERIMENTS.md §Dry-run)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(args, devices="8"):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"),
               REPRO_DRYRUN_DEVICES=devices)
    return subprocess.run([sys.executable, "-m", "repro.launch.dryrun", *args],
                          env=env, capture_output=True, text=True, timeout=900)


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("mamba2-1.3b", "long_500k"),
    ("internvl2-1b", "prefill_32k"),
])
def test_dryrun_debug_mesh(arch, shape):
    r = _run(["--arch", arch, "--shape", shape, "--debug-mesh"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_dryrun_multi_pod_debug_mesh():
    r = _run(["--arch", "qwen2-1.5b", "--shape", "decode_32k", "--debug-mesh",
              "--multi-pod"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_dryrun_robust_mode():
    r = _run(["--arch", "qwen2-1.5b", "--shape", "train_4k", "--debug-mesh",
              "--robust"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_dryrun_skip_reasons():
    r = _run(["--arch", "hubert-xlarge", "--shape", "decode_32k", "--debug-mesh"])
    assert r.returncode == 0
    assert "SKIP" in r.stdout and "encoder-only" in r.stdout
    r = _run(["--arch", "codeqwen1.5-7b", "--shape", "long_500k", "--debug-mesh"])
    assert r.returncode == 0
    assert "SKIP" in r.stdout and "sub-quadratic" in r.stdout


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %all-gather.1 = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p0), replica_groups={}
  %x = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
  ROOT %all-reduce.2 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%sum
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 128 * 4 * 2  # counted for both ring phases
    assert out["total"] == out["all-gather"] + out["all-reduce"]
