"""Integration: the dry-run machinery end-to-end in a subprocess with a small
placeholder-device mesh (8 devices, 2x2 / 2x2x2). Exercises lowering, SPMD
compile, cost/memory analysis and the collective-bytes parser for one arch per
step kind. The full 512-device production sweep is run by
`python -m repro.launch.dryrun --all` (see EXPERIMENTS.md §Dry-run)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(args, devices="8"):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"),
               REPRO_DRYRUN_DEVICES=devices)
    return subprocess.run([sys.executable, "-m", "repro.launch.dryrun", *args],
                          env=env, capture_output=True, text=True, timeout=900)


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("mamba2-1.3b", "long_500k"),
    ("internvl2-1b", "prefill_32k"),
])
def test_dryrun_debug_mesh(arch, shape):
    r = _run(["--arch", arch, "--shape", shape, "--debug-mesh"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_dryrun_multi_pod_debug_mesh():
    r = _run(["--arch", "qwen2-1.5b", "--shape", "decode_32k", "--debug-mesh",
              "--multi-pod"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_dryrun_robust_mode():
    r = _run(["--arch", "qwen2-1.5b", "--shape", "train_4k", "--debug-mesh",
              "--robust"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_dryrun_skip_reasons():
    r = _run(["--arch", "hubert-xlarge", "--shape", "decode_32k", "--debug-mesh"])
    assert r.returncode == 0
    assert "SKIP" in r.stdout and "encoder-only" in r.stdout
    r = _run(["--arch", "codeqwen1.5-7b", "--shape", "long_500k", "--debug-mesh"])
    assert r.returncode == 0
    assert "SKIP" in r.stdout and "sub-quadratic" in r.stdout


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %all-gather.1 = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p0), replica_groups={}
  %x = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
  ROOT %all-reduce.2 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%sum
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 128 * 4 * 2  # counted for both ring phases
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_collective_parser_async_ops():
    """Async HLO pairs must count ONCE, with the same bytes as the sync
    lowering: only the -start op's RESULT tuple element is counted (the tuple
    repeats the operand shape), and the -done op is rejected — it must not
    register as a second all-reduce or a spurious all-gather."""
    from repro.utils import collective_bytes
    hlo = """
  %ar = (f32[8]{0}, f32[8]{0}) all-reduce-start(f32[8]{0} %x), to_apply=%sum
  %ard = f32[8]{0} all-reduce-done((f32[8]{0}, f32[8]{0}) %ar)
  %ags = (f32[8]{0}, f32[16]{0}) all-gather-start(f32[8]{0} %y), replica_groups={}
  %agd = f32[16]{0} all-gather-done((f32[8]{0}, f32[16]{0}) %ags)
  %cps = (f32[32]{0}, f32[32]{0}, u32[], u32[]) collective-permute-start(f32[32]{0} %z)
  %var = ((f32[64]{0}, f32[4]{0}), (f32[64]{0}, f32[4]{0})) all-to-all-start(f32[64]{0} %p, f32[4]{0} %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 4 * 2   # results half only, x2 ring
    assert out["all-gather"] == 16 * 4      # the result, not the operand
    assert out["collective-permute"] == 32 * 4  # u32[] context scalars skipped
    assert out["all-to-all"] == (64 + 4) * 4    # variadic: BOTH results count
    # an op NAME referenced as an operand (%-less print style) is not an op
    assert collective_bytes("  add.9 = f32[8]{0} add(y.2, all-reduce.3)") \
        == {k: 0 for k in list(out) if k != "total"} | {"total": 0}
