"""Hierarchical cross-pod aggregation (dist/hierarchy.py).

Parity: on a forced 8-device host mesh (2 pods × 2 data × 2 model) every
hierarchical rule must match the single-host stacked path to allclose —
including non-uniform weights, replicated (indivisible) leaves, and m=1.
HLO: the lowered hierarchical aggregator must contain NO all-gather of the
stacked momentum leaves — the distance reductions communicate only
(m,)-sized partials over the pod axis.

The multi-device tests need ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
set BEFORE jax initializes, which the tier-1 conftest deliberately does not do
(smoke benches must see the single real CPU). Under plain tier-1 they skip and
``test_hier_parity_subprocess`` re-runs this file in a subprocess with the
flag, so the suite is always exercised. CI additionally runs the in-process
variant directly (see .github/workflows/ci.yml).
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import resolve
from repro.dist.context import mesh_context

ROOT = Path(__file__).resolve().parents[1]

multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# (spec, resolve kwargs) — the acceptance sweep plus the anchor rules
SPECS = [
    ("ctma:cwmed", {"lam": 0.25}),
    ("ctma:gm", {"lam": 0.25, "iters": 8}),
    ("gm", {"iters": 8}),
    ("krum", {"n_byz": 2}),
    ("cwmed", {}),
    ("cwtm", {"lam": 0.2}),
    ("mean", {}),
]


def _mesh():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


def _tree(m=6, seed=0):
    k = jax.random.PRNGKey(seed)
    tree = {
        "a": jax.random.normal(jax.random.fold_in(k, 1), (m, 4, 8)),
        "b": {"c": jax.random.normal(jax.random.fold_in(k, 2), (m, 10)),
              # 5 divides by neither pod nor model: replicated leaf, exercising
              # the covered/total partial-sum scaling
              "d": jax.random.normal(jax.random.fold_in(k, 3), (m, 5))},
    }
    s = jax.random.uniform(jax.random.fold_in(k, 4), (m,), minval=0.2, maxval=2.5)
    return tree, s


def _flat(tree):
    return jnp.concatenate(
        [l.reshape(-1) for l in jax.tree_util.tree_leaves(tree)])


# ---------------------------------------------------------------------------
# Layout policy (single-device safe)
# ---------------------------------------------------------------------------

def test_momentum_pspec_policy():
    from repro.dist.hierarchy import momentum_pspec

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 2}

    mesh = FakeMesh()
    # pod on the trailing-most divisible dim, model on another, G never sharded
    assert tuple(momentum_pspec((8, 6, 4), mesh)) == (None, "model", "pod")
    # single divisible trailing dim: pod wins, model declines
    assert tuple(momentum_pspec((8, 5, 4), mesh)) == (None, None, "pod")
    # nothing divisible: fully replicated
    assert tuple(momentum_pspec((8, 5), mesh)) == (None, None)


def test_has_hier_capability_probe():
    """The launch layer keys the pod-sharded momentum layout and the dry-run
    agg_hier flag on this probe — it must deny rules whose stacked path would
    silently fall back."""
    from repro.agg import has_hier

    assert has_hier("ctma:cwmed", lam=0.25)
    assert has_hier("ctma:gm", lam=0.25)
    assert has_hier("gm") and has_hier("krum") and has_hier("cwmed")
    assert not has_hier("zeno", lam=0.25)
    assert not has_hier("bucketing:cwmed", lam=0.25)
    assert not has_hier("ctma:krum", lam=0.25)   # unsupported anchor
    assert not has_hier("ctma:cwmed@jnp", lam=0.25)  # pinned single-host
    assert not has_hier("no_such_rule")


def test_hier_pins_flat_matrix_inputs():
    """@hier must honor the pin for flat (m, d) inputs too — they route
    through the hierarchical wrapper as the single-leaf stacked case instead
    of silently taking the flat path."""
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (5, 8))
    s = jax.random.uniform(jax.random.fold_in(k, 1), (5,), minval=0.2, maxval=2.0)
    got = resolve("ctma:cwmed@hier", lam=0.25)(x, s)
    want = resolve("ctma:cwmed@jnp", lam=0.25)(x, s)
    assert got.shape == (8,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_hier_backend_requires_hier_rule():
    """An explicit @hier must fail loudly for rules without a cross-pod path
    (silently degrading to the stacked path would gather the buffers)."""
    with pytest.raises(ValueError, match="hierarchical"):
        resolve("zeno@hier", lam=0.25)
    with pytest.raises(ValueError, match="hierarchical"):
        resolve("bucketing:cwmed@hier", lam=0.25)
    with pytest.raises(ValueError, match="hierarchical"):
        resolve("ctma:krum@hier", lam=0.25)  # unsupported anchor


def test_hier_ctma_routes_base_extras():
    """ctma:gm extras (eps) must reach the anchor on BOTH the hier path and
    its stacked fallback, matching the @jnp stacked routing (they used to be
    silently dropped by the hier builder).

    Anisotropic geometry chosen (checked numerically) so the eps change flips
    the distance RANKING to the anchor — the trim weights depend only on that
    ranking, so the ctma output visibly moves: eps=100 floors every Weiszfeld
    weight (anchor -> weighted mean), eps=1e-8 -> geometric median."""
    k = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(jax.random.fold_in(k, 0), (6, 4))
            * jnp.asarray([1.0, 1.0, 4.0, 8.0])[None, :]}
    s = jax.random.uniform(jax.random.fold_in(k, 1), (6,), minval=0.3, maxval=3.0)
    outs = {}
    for eps in (1e-8, 100.0):
        want = resolve("ctma:gm@jnp", lam=0.35, iters=16, eps=eps)(tree, s)
        outs[eps] = resolve("ctma:gm", lam=0.35, iters=16, eps=eps)(tree, s)
        np.testing.assert_allclose(np.asarray(_flat(outs[eps])),
                                   np.asarray(_flat(want)), atol=1e-6)
    assert float(jnp.max(jnp.abs(_flat(outs[1e-8]) - _flat(outs[100.0])))) > 0.1


def test_hier_falls_back_without_mesh():
    tree, s = _tree()
    for spec, kw in SPECS:
        fn = resolve(f"{spec}@hier", **kw)
        want = resolve(f"{spec}@jnp", **kw)(tree, s)
        np.testing.assert_allclose(np.asarray(_flat(fn(tree, s))),
                                   np.asarray(_flat(want)), atol=1e-6)


# ---------------------------------------------------------------------------
# Multi-device parity (8 forced host devices)
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("spec,kw", SPECS, ids=[s for s, _ in SPECS])
def test_hier_matches_stacked(spec, kw):
    tree, s = _tree()
    stacked = resolve(f"{spec}@jnp", **kw)(tree, s)
    with mesh_context(_mesh()):
        hier = resolve(spec, **kw)(tree, s)       # auto: mesh-aware dispatch
    np.testing.assert_allclose(np.asarray(_flat(hier)),
                               np.asarray(_flat(stacked)), atol=2e-4)


@multi_device
@pytest.mark.parametrize("spec,kw", SPECS, ids=[s for s, _ in SPECS])
def test_hier_matches_stacked_uniform_weights(spec, kw):
    tree, _ = _tree(seed=7)
    stacked = resolve(f"{spec}@jnp", **kw)(tree, None)
    with mesh_context(_mesh()):
        hier = resolve(f"{spec}@hier", **kw)(tree, None)
    np.testing.assert_allclose(np.asarray(_flat(hier)),
                               np.asarray(_flat(stacked)), atol=2e-4)


@multi_device
@pytest.mark.parametrize("spec,kw", SPECS, ids=[s for s, _ in SPECS])
def test_hier_single_worker(spec, kw):
    """m=1 must reduce to the identity on the single row."""
    tree, s = _tree(m=1, seed=3)
    with mesh_context(_mesh()):
        hier = resolve(spec, **kw)(tree, s)
    want = resolve(f"{spec}@jnp", **kw)(tree, s)
    np.testing.assert_allclose(np.asarray(_flat(hier)),
                               np.asarray(_flat(want)), atol=2e-4)


@multi_device
def test_hier_rejects_corrupt_group():
    tree, s = _tree(seed=5)
    corrupt = jax.tree_util.tree_map(lambda x: x.at[0].set(1e8), tree)
    with mesh_context(_mesh()):
        out = resolve("ctma:cwmed", lam=0.3)(corrupt, s)
    assert float(jnp.max(jnp.abs(_flat(out)))) < 100.0


@multi_device
def test_hier_hlo_no_momentum_gather():
    """Acceptance: no all-gather of the stacked leaves; distance reductions
    communicate only m-sized partials over the reduce axes."""
    from repro.dist.sharding import hier_momentum_sharding
    from repro.utils import collective_bytes
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _mesh()
    tree, s = _tree()
    m = s.shape[0]
    for spec, kw, passes in [("ctma:cwmed", {"lam": 0.25}, 1),
                             ("gm", {"iters": 8}, 8),
                             ("krum", {"n_byz": 2}, m)]:
        fn = resolve(spec, **kw)
        with mesh_context(mesh):
            jf = jax.jit(fn, in_shardings=(hier_momentum_sharding(mesh, tree),
                                           NamedSharding(mesh, P())))
            cb = collective_bytes(jf.lower(tree, s).compile().as_text())
        assert cb["all-gather"] == 0, (spec, cb)
        # all-reduce bytes: <= passes × (m or m×m) f32 partials × 2 ring phases
        assert cb["all-reduce"] <= passes * m * m * 4 * 2, (spec, cb)
        assert cb["all-reduce"] > 0, (spec, "hier path did not engage")


@multi_device
def test_hier_robust_train_step_two_pods():
    """End-to-end: the robust-DP train step lowered under a multi-pod mesh
    context trains, and its losses stay finite with a Byzantine group."""
    from repro.configs import smoke_config
    from repro.data import lm_batches
    from repro.dist.steps import (RobustDPConfig, init_train_state,
                                  make_robust_train_step)
    from repro.optim import OptConfig

    mesh = _mesh()
    cfg = smoke_config("qwen2-1.5b")
    opt = OptConfig(name="mu2", lr=3e-3, gamma=0.1, beta=0.25)
    rcfg = RobustDPConfig(n_groups=4, agg="ctma:cwmed", lam=0.3,
                          byz_groups=(1,), byz_attack="sign_flip")
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0), rcfg)
    data = lm_batches(cfg, 8, 32)
    with mesh, mesh_context(mesh):
        step = jax.jit(make_robust_train_step(cfg, opt, rcfg))
        for _ in range(3):
            state, metrics = step(state, {k: jnp.asarray(v)
                                          for k, v in next(data).items()})
            assert np.isfinite(float(metrics["loss"]))


# ---------------------------------------------------------------------------
# Tier-1 gates (single-device): run the suite above in a subprocess
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="already running in the multi-device variant")
@pytest.mark.skipif(os.environ.get("CI") == "true",
                    reason="CI runs the dedicated in-process parity step")
def test_hier_parity_subprocess():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(Path(__file__)),
         "-k", "not subprocess and not dryrun"],
        env=env, capture_output=True, text=True, timeout=1800, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "passed" in r.stdout, r.stdout   # the parity sweep actually ran


def test_hier_dryrun_multi_pod_robust():
    """launch/dryrun.py end-to-end: the robust multi-pod signature lowers with
    the hierarchical path engaged (asserted via the 'agg=hier' marker — a
    silent fallback to the gathering stacked path would keep the compile
    green but drop the marker)."""
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"),
               REPRO_DRYRUN_DEVICES="8")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
         "--shape", "train_4k", "--debug-mesh", "--multi-pod", "--robust",
         "--no-probe"],
        env=env, capture_output=True, text=True, timeout=900, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
    assert "agg=hier" in r.stdout
