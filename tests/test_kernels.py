"""Pallas kernel sweeps: shapes × dtypes, assert_allclose vs the pure-jnp
oracles (interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.wctma_fused import wctma_fused
from repro.kernels.wreduce import sqdist_pallas, wcomb_pallas

KEY = jax.random.PRNGKey(0)

SHAPES_MD = [(3, 7), (5, 128), (9, 512), (16, 1000), (32, 2048), (8, 513)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,d", SHAPES_MD)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_wcwmed_sweep(m, d, dtype):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, m * d))
    x = jax.random.normal(k1, (m, d)).astype(dtype)
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    np.testing.assert_allclose(np.asarray(ops.wcwmed(x, s)),
                               np.asarray(ref.wcwmed_ref(x, s)),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("m,d", SHAPES_MD[:4])
def test_wcwmed_tie_handling(m, d):
    x = jax.random.normal(jax.random.fold_in(KEY, d), (m, d))
    s = jnp.ones((m,))  # even m hits the exact S/2 prefix tie
    np.testing.assert_allclose(np.asarray(ops.wcwmed(x, s)),
                               np.median(np.asarray(x), axis=0), atol=1e-6)


@pytest.mark.parametrize("m,d", SHAPES_MD)
def test_sqdist_and_wcomb(m, d):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, 7 * m + d), 3)
    x = jax.random.normal(k1, (m, d))
    y = jax.random.normal(k2, (d,))
    c = jax.random.uniform(k3, (m,), minval=0.0, maxval=2.0)
    np.testing.assert_allclose(np.asarray(sqdist_pallas(x, y)),
                               np.asarray(ref.sqdist_ref(x, y)), rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(wcomb_pallas(x, c, 3.7)),
                               np.asarray(ref.wcomb_ref(x, c, 3.7)), rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("m,d", SHAPES_MD[:4])
def test_wgm_kernel_matches_oracle(m, d):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, m + d))
    x = jax.random.normal(k1, (m, d))
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    np.testing.assert_allclose(np.asarray(ops.wgm(x, s, iters=8)),
                               np.asarray(ref.wgm_ref(x, s, iters=8)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m,d", SHAPES_MD[:4])
@pytest.mark.parametrize("lam", [0.1, 0.3])
def test_wctma_kernel_matches_oracle(m, d, lam):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 3 * m + d))
    x = jax.random.normal(k1, (m, d))
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    np.testing.assert_allclose(np.asarray(ops.wctma(x, s, lam=lam)),
                               np.asarray(ref.wctma_ref(x, s, lam)),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused ω-CTMA (single-pass anchor + distances, then one trimmed combine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d", SHAPES_MD)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
@pytest.mark.parametrize("lam", [0.1, 0.3])
def test_wctma_fused_sweep(m, d, dtype, lam):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 11 * m + d))
    x = jax.random.normal(k1, (m, d)).astype(dtype)
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    np.testing.assert_allclose(np.asarray(wctma_fused(x, s, lam=lam)),
                               np.asarray(ref.wctma_ref(x, s, lam)),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("m,d", SHAPES_MD[:4])
def test_wctma_fused_exact_tie_anchor(m, d):
    """Even m + unit weights hits the exact S/2 prefix tie in the fused
    anchor pass (paper's average-the-adjacent-pair rule)."""
    me = m + (m % 2)  # force even worker count
    x = jax.random.normal(jax.random.fold_in(KEY, 13 * d), (me, d))
    s = jnp.ones((me,))
    np.testing.assert_allclose(np.asarray(wctma_fused(x, s, lam=0.25)),
                               np.asarray(ref.wctma_ref(x, s, 0.25)),
                               atol=1e-5, rtol=1e-5)


def test_wctma_fused_boundary_row_clipping():
    """(1-λ)·Σs falls strictly inside a row's weight interval: the boundary
    row must be kept with exactly the clipped partial mass."""
    x = jnp.stack([jnp.zeros(64), jnp.ones(64), 2.0 * jnp.ones(64),
                   100.0 * jnp.ones(64)])
    s = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    lam = 0.3  # thresh = 2.8 -> kept (sorted by dist) = [1, 1, 0.8, 0]
    got = wctma_fused(x, s, lam=lam)
    want = ref.wctma_ref(x, s, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # the far outlier must be fully trimmed, not merely down-weighted
    assert float(jnp.max(got)) < 2.0


def test_wctma_fused_matches_unfused():
    x = jax.random.normal(jax.random.fold_in(KEY, 77), (9, 777))
    s = jax.random.uniform(jax.random.fold_in(KEY, 78), (9,), minval=0.1, maxval=3.0)
    np.testing.assert_allclose(
        np.asarray(ops.wctma(x, s, lam=0.2, fused=True)),
        np.asarray(ops.wctma(x, s, lam=0.2, fused=False)), atol=1e-5, rtol=1e-5)


def test_wgm_trace_size_independent_of_iters():
    """The fori_loop rewrite must trace the fused Weiszfeld step ONCE: launch
    count and trace size may not grow with iters (previously 1 + 2·iters
    pallas_call launches were unrolled into every trace)."""
    x = jax.random.normal(KEY, (9, 512))
    s = jnp.ones((9,))
    j2 = jax.make_jaxpr(lambda x, s: ops.wgm(x, s, iters=2))(x, s)
    j16 = jax.make_jaxpr(lambda x, s: ops.wgm(x, s, iters=16))(x, s)
    n2, n16 = str(j2).count("pallas_call"), str(j16).count("pallas_call")
    assert n2 == n16 == 2, (n2, n16)  # anchor pass + ONE fused loop body
    assert len(j2.eqns) == len(j16.eqns)


def test_kernel_aggregator_registry_matches_jnp():
    from repro.agg import resolve
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (8, 300))
    s = jax.random.uniform(jax.random.fold_in(KEY, 6), (8,), minval=0.2, maxval=2.0)
    for spec in ("mean", "cwmed", "gm", "ctma:cwmed", "ctma:gm"):
        got = resolve(spec, lam=0.25, backend="pallas")(x, s)
        want = resolve(spec, lam=0.25, backend="jnp")(x, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4, err_msg=spec)


SWA_CASES = [
    # B, H, KV, hd, W, local, pos
    (2, 8, 2, 64, 512, True, 100),
    (2, 8, 2, 64, 512, True, 5000),   # wrapped ring
    (1, 4, 4, 128, 256, False, 255),
    (2, 16, 1, 64, 1024, True, 37),
    (1, 2, 2, 32, 256, False, 0),     # first token
]


@pytest.mark.parametrize("B,H,KV,hd,W,local,pos", SWA_CASES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_swa_decode_sweep(B, H, KV, hd, W, local, pos, dtype):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, B * H * W + pos), 3)
    q = jax.random.normal(k1, (B, H, hd)).astype(dtype)
    kc = jax.random.normal(k2, (B, W, KV, hd)).astype(dtype)
    vc = jax.random.normal(k3, (B, W, KV, hd)).astype(dtype)
    p = jnp.asarray(pos, jnp.int32)
    got = ops.swa_decode(q, kc, vc, p, local=local)
    want = ref.swa_decode_ref(q, kc, vc, p, local=local)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,KV,hd,W,local", [
    (3, 8, 2, 64, 512, True),
    (3, 8, 2, 64, 512, False),
    (4, 4, 4, 32, 256, True),
    (2, 16, 1, 64, 128, False),
])
def test_swa_decode_per_slot_pos_sweep(B, H, KV, hd, W, local):
    """Vector (B,) pos — the slot-mapped serving form. Rows at depth 0, a
    partially-filled cache, exactly W-1, and a wrapped ring must all match
    the masked-SDPA oracle row-for-row (this used to fall back to SDPA)."""
    k1, k2, k3, k4 = jax.random.split(jax.random.fold_in(KEY, B * W), 4)
    q = jax.random.normal(k1, (B, H, hd))
    kc = jax.random.normal(k2, (B, W, KV, hd))
    vc = jax.random.normal(k3, (B, W, KV, hd))
    pos = jax.random.randint(k4, (B,), 0, 3 * W).astype(jnp.int32)
    pos = pos.at[0].set(0).at[1].set(W - 1)          # edge depths
    got = ops.swa_decode(q, kc, vc, pos, local=local)
    want = ref.swa_decode_ref(q, kc, vc, pos, local=local)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # each row must equal its own scalar-pos decode (per-slot independence)
    for b in range(B):
        solo = ref.swa_decode_ref(q[b:b + 1], kc[b:b + 1], vc[b:b + 1],
                                  pos[b], local=local)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(solo[0]),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# paged flash decode (block-table page pools — serve/cache.py layout)
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # S, H, KV, hd, P, pages_per_slot
    (3, 8, 2, 64, 16, 4),
    (4, 4, 4, 32, 8, 6),
    (2, 16, 1, 64, 32, 2),
    (1, 2, 2, 32, 4, 7),
]


def _paged_fixture(S, H, KV, hd, P, pps, seed):
    """Random pools + a permuted block table + per-slot pos exercising
    depth 0, a partially-filled last page, and the full span."""
    n_pages = S * pps
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 4)
    q = jax.random.normal(ks[0], (S, H, hd))
    kp = jax.random.normal(ks[1], (n_pages + 1, P, KV, hd))
    vp = jax.random.normal(ks[2], (n_pages + 1, P, KV, hd))
    perm = np.random.default_rng(seed).permutation(n_pages)
    tbl = jnp.asarray(perm.reshape(S, pps), jnp.int32)
    pos = jax.random.randint(ks[3], (S,), 0, pps * P).astype(jnp.int32)
    pos = pos.at[0].set(0)                       # first token
    if S > 1:
        pos = pos.at[1].set(pps * P - 1)         # full span
    if S > 2:
        pos = pos.at[2].set(P + P // 2)          # partially-filled last page
    return q, kp, vp, tbl, pos


@pytest.mark.parametrize("S,H,KV,hd,P,pps", PAGED_CASES)
def test_paged_decode_sweep(S, H, KV, hd, P, pps):
    q, kp, vp, tbl, pos = _paged_fixture(S, H, KV, hd, P, pps, seed=S * P)
    got = ops.paged_decode(q, kp, vp, tbl, pos)
    want = ref.paged_decode_ref(q, kp, vp, tbl, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_matches_dense_gather():
    """Paging is a pure relayout: gathering each slot's pages into a dense
    cache and running the dense causal kernel must give the same output."""
    S, H, KV, hd, P, pps = 3, 4, 2, 32, 8, 4
    q, kp, vp, tbl, pos = _paged_fixture(S, H, KV, hd, P, pps, seed=99)
    got = ops.paged_decode(q, kp, vp, tbl, pos)
    kc = kp[tbl].reshape(S, pps * P, KV, hd)
    vc = vp[tbl].reshape(S, pps * P, KV, hd)
    want = ref.swa_decode_ref(q, kc, vc, pos, local=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_dump_pages_masked():
    """Logical pages past ``pos`` may point at the dump page (unallocated):
    whatever garbage lives there must not change the output."""
    S, H, KV, hd, P, pps = 2, 4, 2, 32, 8, 4
    q, kp, vp, tbl, pos = _paged_fixture(S, H, KV, hd, P, pps, seed=7)
    pos = jnp.asarray([P - 2, 2 * P + 1], jnp.int32)   # 1 / 3 pages allocated
    dump = kp.shape[0] - 1
    tbl_dumped = tbl.at[0, 1:].set(dump).at[1, 3:].set(dump)
    a = ops.paged_decode(q, kp, vp, tbl, pos)
    b = ops.paged_decode(q, kp, vp, tbl_dumped, pos)
    # poison the dump page: still identical
    kp2 = kp.at[dump].set(1e4)
    vp2 = vp.at[dump].set(-1e4)
    c = ops.paged_decode(q, kp2, vp2, tbl_dumped, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 10), st.integers(1, 50), st.integers(0, 10_000))
def test_wcwmed_property_random(m, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (m, d))
    s = jax.random.uniform(k2, (m,), minval=0.05, maxval=5.0)
    np.testing.assert_allclose(np.asarray(ops.wcwmed(x, s)),
                               np.asarray(ref.wcwmed_ref(x, s)), atol=1e-5)


SSD_CASES = [(2, 32, 4, 8, 16, 8), (1, 64, 8, 16, 32, 16), (2, 128, 2, 4, 8, 32)]


@pytest.mark.parametrize("b,s,h,p,n,c", SSD_CASES)
def test_ssd_kernel_matches_oracle(b, s, h, p, n, c):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h + b), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y1, st1 = ops.ssd_scan(x, dt, A, B, C, c)
    y0, st0 = ref.ssd_ref(x, dt, A, B, C, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st0), atol=1e-3, rtol=1e-3)
