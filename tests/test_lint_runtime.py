"""Compile-count pins via repro.lint_runtime.compile_count().

These are the regression guards the compile-amortization architecture
promised but never enforced:

- **fleet**: ONE XLA backend compile per compile-signature group
  (fleet/scenario.py grouping feeding ``jit(vmap(step))``), and ZERO new
  compiles when the same group re-runs same-signature scenarios (different
  seeds / Byzantine masses / the weighted flag are traced data).
- **scheduler**: a fresh chunked ServeEngine warmup costs exactly ONE
  compile per token-budget SHAPE CLASS — the mixed (S + chunk_rows, C)
  batch and the decode-only (S, 1) batch, i.e. 2 total, whatever the
  workload's prompt-length mix — and any synthetic workload after warmup
  (including one with an entirely different length mix) recompiles
  NOTHING. The legacy bucketed trio keeps its old pin: one prefill compile
  per prompt bucket plus the decode step and first-token sampler
  (n_buckets + 2).
- **bisection**: breakdown-matrix probes over Byzantine mass reuse the
  already-compiled fleet step (fleet/matrix.py ``run_cached``) — a second
  matrix pass with a shared group cache is compile-free.

Counting is process-global, so every pin measures a DELTA after a
throwaway warm pass over identical shapes (jnp eager ops compile per shape
on first use; see lint_runtime docstring).
"""
import copy
import math

import jax
import jax.numpy as jnp
import pytest

from repro.fleet import (FleetGroup, Scenario, breakdown_matrix,
                         matrix_scenarios, run_scenarios)
from repro.lint_runtime import (BACKEND_COMPILE_EVENT, compile_count,
                                warmup_eager_cache)
from repro.models import ModelConfig, init_lm
from repro.serve import ServeConfig, ServeEngine, synth_workload

QUAD = Scenario(problem="quadratic", attack="sign_flip", agg="ctma:cwmed",
                m=5, byz_frac=0.2, steps=6, batch=4, seed=0)

V = 64
DENSE = ModelConfig(name="dense", n_layers=2, d_model=32, n_heads=4, n_kv=2,
                    d_ff=64, vocab=V, qkv_bias=True)
SCFG = ServeConfig(n_slots=3, max_len=64, max_prefill_batch=2)


@pytest.fixture(scope="module", autouse=True)
def _warm_eager():
    warmup_eager_cache()


# ---------------------------------------------------------------------------
# the sentinel itself
# ---------------------------------------------------------------------------

def test_sentinel_counts_compiles_and_cache_hits():
    f = jax.jit(lambda x: x * 2.0 + 1.0)
    with compile_count() as c1:
        f(jnp.ones(16)).block_until_ready()
    assert c1.count >= 1
    assert any(ev == BACKEND_COMPILE_EVENT for ev, _ in c1.events)
    with compile_count() as c2:
        f(jnp.ones(16)).block_until_ready()
    assert c2.count == 0          # cache hit: no backend compile
    with compile_count() as c3:
        f(jnp.ones(32)).block_until_ready()
    assert c3.count >= 1          # new shape: recompile

    # a deactivated counter must not keep tallying after the block exits
    n = c3.count
    jax.jit(lambda x: x - 3.0)(jnp.ones(16)).block_until_ready()
    assert c3.count == n


# ---------------------------------------------------------------------------
# fleet: one compile group per shape class
# ---------------------------------------------------------------------------

def test_fleet_one_compile_per_signature_group():
    one_group = [QUAD, QUAD._replace(seed=3)]
    two_groups = [QUAD, QUAD._replace(agg="cwmed")]
    # throwaway pass: warm per-shape eager caches for both group layouts
    run_scenarios(one_group)
    run_scenarios(two_groups)

    with compile_count() as c1:
        run_scenarios(one_group)
    assert c1.count == 1, c1.events

    with compile_count() as c2:
        run_scenarios(two_groups)
    assert c2.count == 2, c2.events


def test_fleet_group_rerun_is_compile_free():
    grp = FleetGroup([QUAD, QUAD._replace(seed=3)])
    grp.run()
    # same compile signature, different traced knobs: byz mass, seed,
    # weighted ablation — all must ride the already-compiled vmapped step
    with compile_count() as c:
        grp.run([QUAD._replace(seed=9),
                 QUAD._replace(byz_frac=0.6, weighted=False)])
    assert c.count == 0, c.events


# ---------------------------------------------------------------------------
# scheduler: one compile per token-budget shape class (chunked default)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_params():
    return init_lm(jax.random.PRNGKey(0), DENSE)


def test_scheduler_one_compile_per_shape_class(dense_params):
    reqs = synth_workload(8, V, seed=0, prompt_lens=(4, 24), gen_lens=(2, 8))
    # throwaway engine warms every eager-op shape this workload touches
    ServeEngine(DENSE, dense_params, SCFG).run(
        [copy.deepcopy(r) for r in reqs])

    eng = ServeEngine(DENSE, dense_params, SCFG)
    assert eng.chunked            # no explicit buckets -> unified step
    with compile_count() as cw:
        eng.warmup([r.prompt_len for r in reqs])
    # ONE compile per batch shape class: mixed (S + chunk_rows, chunk_size)
    # + decode-only (S, 1) — independent of the prompt-length mix
    assert cw.count == 2, cw.events

    with compile_count() as cr:
        eng.run([copy.deepcopy(r) for r in reqs], warmup=False)
    assert cr.count == 0, cr.events

    # an entirely different prompt-length mix rides the same two compiles
    other = synth_workload(6, V, seed=5, prompt_lens=(2, 40),
                           gen_lens=(2, 6))
    with compile_count() as c2:
        eng.run([copy.deepcopy(r) for r in other], warmup=False)
    assert c2.count == 0, c2.events


def test_scheduler_legacy_bucketed_keeps_per_bucket_pin(dense_params):
    scfg = ServeConfig(n_slots=3, max_len=64, max_prefill_batch=2,
                       chunked=False)
    reqs = synth_workload(8, V, seed=0, prompt_lens=(4, 24), gen_lens=(2, 8))
    ServeEngine(DENSE, dense_params, scfg).run(
        [copy.deepcopy(r) for r in reqs])                # warm eager shapes

    eng = ServeEngine(DENSE, dense_params, scfg)
    assert not eng.chunked
    lens = [r.prompt_len for r in reqs]
    n_buckets = len({eng.sched._bucket_for(l) for l in lens})
    assert n_buckets >= 2         # the workload must actually span buckets

    with compile_count() as cw:
        eng.warmup(lens)
    # one prefill compile per bucket + the decode step + first-token sampler
    assert cw.count == n_buckets + 2, cw.events

    with compile_count() as cr:
        eng.run([copy.deepcopy(r) for r in reqs], warmup=False)
    assert cr.count == 0, cr.events


# ---------------------------------------------------------------------------
# obs: telemetry must not change the compile story
# ---------------------------------------------------------------------------

def test_fleet_obs_group_is_one_compile_and_rerun_free(tmp_path):
    """A metric-collecting fleet group is still ONE compile (the engine.*
    outputs ride the same jitted vmapped step), and re-running it with
    different traced knobs stays compile-free."""
    from repro.obs import MetricSink, RunObs
    obs = RunObs(sink=MetricSink(tmp_path / "m.jsonl"), device_metrics=True)
    scs = [QUAD, QUAD._replace(seed=3)]
    FleetGroup(scs, collect_metrics=True).run(obs=obs)   # warm eager shapes
    with compile_count() as c1:
        grp = FleetGroup(scs, collect_metrics=True)
        grp.run(obs=obs)
    assert c1.count == 1, c1.events
    with compile_count() as c2:
        grp.run([QUAD._replace(seed=9),
                 QUAD._replace(byz_frac=0.6, weighted=False)], obs=obs)
    assert c2.count == 0, c2.events
    obs.close()


def test_scheduler_obs_keeps_compile_pins(dense_params, tmp_path):
    """Host-side obs (spans + rows) on a chunked ServeEngine keeps the exact
    warmup compile count (2: one per unified shape class) and a compile-free
    run — the single-engine jitted steps are untouched by instrumentation."""
    from repro.obs import RunObs
    reqs = synth_workload(8, V, seed=0, prompt_lens=(4, 24), gen_lens=(2, 8))
    ServeEngine(DENSE, dense_params, SCFG).run(
        [copy.deepcopy(r) for r in reqs])                # warm eager shapes

    obs = RunObs.open(tmp_path, "serve", compile_events=False)
    eng = ServeEngine(DENSE, dense_params, SCFG, obs=obs)
    assert eng.chunked
    with compile_count() as cw:
        eng.warmup([r.prompt_len for r in reqs])
    assert cw.count == 2, cw.events
    with compile_count() as cr:
        eng.run([copy.deepcopy(r) for r in reqs], warmup=False)
    assert cr.count == 0, cr.events
    obs.close()


# ---------------------------------------------------------------------------
# breakdown bisection: probes reuse the compiled step
# ---------------------------------------------------------------------------

def test_bisection_probes_are_compile_free():
    scs = matrix_scenarios(problem="quadratic", attacks=("sign_flip",),
                           aggs=("ctma:cwmed",), arrivals=("proportional",),
                           alphas=(math.inf,), m=5, byz_frac=0.2, steps=8,
                           batch=4)
    cache = {}
    # first pass compiles the group(s) into the shared cache
    rows1 = breakdown_matrix(scs, bisect_steps=6, time_aggs=False,
                             cache=cache)
    assert len(cache) >= 1
    # the entire second matrix — including every bisection probe — must
    # ride the cached compiled steps (time_aggs=False: the agg timer jits
    # a fresh fn per call by design and is excluded from the pin)
    with compile_count() as c:
        rows2 = breakdown_matrix(scs, bisect_steps=6, time_aggs=False,
                                 cache=cache)
    assert c.count == 0, c.events
    assert rows1[0]["final_loss"] == rows2[0]["final_loss"]
