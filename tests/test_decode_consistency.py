"""Prefill + incremental decode must reproduce teacher-forced logits for every
stateful mixer (ring-buffer sliding window, SSD state handoff, RG-LRU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, decode_step, forward, init_lm, prefill

B, V = 2, 64
S_PROMPT, S_DEC = 16, 8
S = S_PROMPT + S_DEC

CFGS = [
    ModelConfig(name="dense", n_layers=4, d_model=64, n_heads=4, n_kv=2,
                d_ff=128, vocab=V, qkv_bias=True),
    ModelConfig(name="swa", n_layers=6, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                vocab=V, window=8, global_every=3, qk_norm=True, head_dim=32),
    ModelConfig(name="moe", arch_type="moe", n_layers=4, d_model=64, n_heads=4,
                n_kv=4, d_ff=128, vocab=V, n_experts=4, top_k=2, n_shared=1,
                d_expert=64, capacity_factor=8.0),
    ModelConfig(name="ssm", arch_type="ssm", n_layers=4, d_model=64, n_heads=1,
                n_kv=1, d_ff=0, vocab=V, ssm_state=16, ssm_head_dim=16, ssm_chunk=8),
    ModelConfig(name="hyb", arch_type="hybrid", n_layers=6, d_model=64, n_heads=4,
                n_kv=1, d_ff=128, vocab=V, block_pattern=("rec", "rec", "local"),
                window=8, lru_width=64),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_decode_matches_teacher_forcing(cfg):
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, V)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    pl_, cache = prefill(params, cfg, {"tokens": toks[:, :S_PROMPT]}, max_len=S)
    errs = [float(jnp.max(jnp.abs(pl_[:, -1] - full_logits[:, S_PROMPT - 1])))]
    for t in range(S_PROMPT, S):
        logits_t, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(logits_t[:, 0] - full_logits[:, t]))))
    assert max(errs) < 2e-3, errs


def test_ring_buffer_wraps():
    """Decode far past the window: ring slots recycle without corruption."""
    cfg = ModelConfig(name="ring", n_layers=2, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=32, window=4, global_every=0)
    cfg = cfg.with_(window=4)
    key = jax.random.PRNGKey(1)
    params = init_lm(key, cfg)
    toks = jax.random.randint(key, (1, 24), 0, 32)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    pl_, cache = prefill(params, cfg, {"tokens": toks[:, :4]}, max_len=24)
    for t in range(4, 24):
        logits_t, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        err = float(jnp.max(jnp.abs(logits_t[:, 0] - full_logits[:, t])))
        assert err < 2e-3, (t, err)
