"""Paper Figure 4 / Figure 7: μ²-SGD vs standard momentum vs SGD in the
asynchronous Byzantine setup (history matters: SGD lags both)."""
from __future__ import annotations

from repro.optim import OptConfig

from .common import fmt_row, run_async_experiment

# 9 workers, 4 Byzantine with update mass (3+4+5+6)/45 = 0.4 = the paper's λ
SETUP = dict(m=9, byz=(2, 3, 4, 5), arrival="proportional", steps=600,
             agg="ctma:cwmed", lam=0.4)
OPTS = {
    "mu2": OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25),
    "momentum": OptConfig(name="momentum", lr=0.05, beta=0.9),
    "sgd": OptConfig(name="sgd", lr=0.05),
}


def run(full: bool = False):
    rows = []
    for attack in ("sign_flip", "label_flip"):
        accs = {}
        us = 0.0
        for name, opt in OPTS.items():
            r = run_async_experiment(attack=attack, opt=opt, **SETUP)
            accs[name] = r["acc"]
            us = r["us_per_step"]
        rows.append(fmt_row(
            f"fig4_{attack}", us,
            ";".join(f"acc_{k}={v:.3f}" for k, v in accs.items())))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
