"""§Roofline reader: renders the per-(arch × shape × mesh) roofline table from
the dry-run artifacts (experiments/dryrun/*.json). Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import fmt_row

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records():
    recs = []
    for p in sorted(ART.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def run(full: bool = False):
    rows = []
    recs = load_records()
    if not recs:
        return [fmt_row("roofline_missing", 0.0, "run repro.launch.dryrun --all first")]
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = len(recs) - n_ok
    for r in recs:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] != "ok":
            rows.append(fmt_row(name, 0.0, f"SKIP:{r['reason'][:40]}"))
            continue
        rf = r["roofline"]
        step_ms = max(rf["compute_s"], rf["memory_s"], rf["collective_s"]) * 1e3
        rows.append(fmt_row(
            name, step_ms * 1e3,
            f"bottleneck={rf['bottleneck']};compute_ms={rf['compute_s']*1e3:.2f};"
            f"memory_ms={rf['memory_s']*1e3:.2f};collective_ms={rf['collective_s']*1e3:.2f};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f}"))
    rows.append(fmt_row("roofline_summary", 0.0, f"ok={n_ok};skipped={n_skip}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
