"""Paper Figure 3 / Figure 6: weighted robust aggregators with and without the
ω-CTMA meta-aggregator across the four attacks."""
from __future__ import annotations

from .common import fmt_row, run_async_experiment

# Byzantine ids chosen so the UPDATE mass matches the paper's λ (Eq. 6):
# (4,5,6) -> (5+6+7)/45 = 0.4;  (3,) -> 4/45 ≈ 0.09.
SETUP = dict(m=9, arrival="proportional", steps=600)
PANELS = [
    ("label_flip", 0.3, (4, 5, 6)),
    ("sign_flip", 0.4, (4, 5, 6)),
    ("little", 0.1, (3,)),
    ("empire", 0.4, (4, 5, 6)),
]


def run(full: bool = False):
    rows = []
    for attack, lam, byz in PANELS:
        for base in ("cwmed", "gm"):
            with_ = run_async_experiment(attack=attack, agg=f"ctma:{base}",
                                         lam=lam, byz=byz, **SETUP)
            without = run_async_experiment(attack=attack, agg=base,
                                           lam=lam, byz=byz, **SETUP)
            rows.append(fmt_row(
                f"fig3_{attack}_{base}", with_["us_per_step"],
                f"acc_ctma={with_['acc']:.3f};acc_base={without['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
