"""Serve-path A/B benchmarks on a skewed-length workload.

Three A/Bs share one workload style:

- static fixed-batch vs continuous-batching decode (short requests pay for
  the longest one in a static batch; continuous retires and backfills slots
  independently);
- dense vs PAGED slot cache (a dense slot pins ``max_len`` KV rows per
  global layer however short the request; the block-table paged cache pins
  only ``ceil((prompt + gen) / page_size)`` pages — serve/cache.py), with
  the HBM-per-request accounting from ``slot_hbm_bytes`` recorded next to
  the decode throughput so the memory win is visible at equal tok/s;
- CHUNKED prefill (unified ragged step) vs the legacy bucketed trio on a
  skewed workload where one LONG prompt arrives mid-decode: the bucketed
  engine pays an in-band XLA prefill compile for the long prompt's unseen
  bucket (plus a whole-prompt prefill stall), while the chunked engine
  streams it through the two already-compiled unified shapes — its TTFT is
  asserted STRICTLY lower, at no decode tok/s regression, with TTFT
  p50/p99 recorded next to decode tok/s (unit ``ms``).

Greedy outputs are asserted token-identical across ALL engine×layout
combinations before any number is reported — a perf/memory figure from
diverging outputs would be meaningless.

Rows follow the orchestrator's ``name,value,unit,derived`` convention
(units here: ``tok_s``, ``ms``, ``frac``, ``ratio``, ``kb``); every
``serve_*`` row is also persisted to ``BENCH_serve.json`` by benchmarks/run.py
so successive PRs accumulate a serving-perf trajectory.
"""
from __future__ import annotations

import copy

import jax

import numpy as np

from repro.configs import smoke_config
from repro.models.lm import init_lm
from repro.serve import (Request, ServeConfig, ServeEngine, slot_hbm_bytes,
                         synth_workload)


def _run_pair(cfg, params, workload, scfg):
    reports = {}
    for engine in ("static", "continuous"):
        reqs = [copy.deepcopy(r) for r in workload]
        reports[engine] = ServeEngine(cfg, params, scfg, engine=engine).run(reqs)
    # greedy outputs must be token-identical across the two engines —
    # a perf number from diverging outputs would be meaningless
    if scfg.temperature <= 0.0:
        for uid, toks in reports["static"].outputs.items():
            assert reports["continuous"].outputs[uid] == toks, \
                f"static/continuous divergence on request {uid}"
    return reports


def _chunked_vs_bucketed(cfg, params) -> list[str]:
    """TTFT A/B: short decode streams running when one LONG prompt arrives.

    Both engines are warmed ONLY on the shapes the short requests need, as a
    real server would be. The bucketed engine then meets the long prompt's
    bucket for the first time mid-serve — an in-band XLA prefill compile on
    the critical path, plus a whole-prompt prefill stall for every decoding
    slot. The chunked engine has no per-length shapes to meet: the long
    prompt streams through the already-compiled unified step. Its TTFT must
    be STRICTLY lower; decode throughput must not regress."""
    rng = np.random.default_rng(7)
    long_len, gen_max = 64, 16
    max_len = long_len + 2 * gen_max
    long_toks = rng.integers(0, cfg.vocab, long_len).astype(np.int32)
    shorts = synth_workload(6, cfg.vocab, seed=3, prompt_lens=(8, 16),
                            gen_lens=(16, 32), short_frac=0.0, rate=0.0)
    kw = dict(n_slots=8, max_len=max_len, max_prefill_batch=4)
    reports, long_ttft = {}, {}
    for tag in ("chunked", "bucketed"):
        reqs = [copy.deepcopy(r) for r in shorts]
        long_req = Request(uid=99, arrival=0.05, max_new_tokens=gen_max,
                           tokens=long_toks.copy())
        reqs.append(long_req)
        eng = ServeEngine(cfg, params,
                          ServeConfig(chunked=(tag == "chunked"), **kw))
        assert eng.chunked == (tag == "chunked")
        # warm on the SHORT prompts only — the long prompt's shapes (if
        # any) are met in band, exactly as in a live server
        eng.warmup([r.prompt_len for r in shorts])
        reports[tag] = eng.run(reqs, warmup=False)
        long_ttft[tag] = long_req.t_first_token - long_req.arrival
    ch, bu = reports["chunked"], reports["bucketed"]
    for uid, toks in bu.outputs.items():
        assert ch.outputs[uid] == toks, \
            f"chunked/bucketed divergence on request {uid}"
    # the headline regression pins: the mid-decode long prompt reaches its
    # first token strictly faster chunked, and decode tok/s does not regress
    assert long_ttft["chunked"] < long_ttft["bucketed"], long_ttft
    tok_ratio = (ch.decode_tok_s / bu.decode_tok_s
                 if bu.decode_tok_s else 0.0)
    assert tok_ratio >= 0.7, f"chunked decode regression: {tok_ratio:.2f}"

    rows = []
    for tag, rep in (("chunked", ch), ("bucketed", bu)):
        rows += [
            f"serve_{tag}_ttft_p50_ms,{rep.ttft_p50_s * 1e3:.1f},ms,"
            f"p99_ms={rep.ttft_p99_s * 1e3:.1f}",
            f"serve_{tag}_long_ttft_ms,{long_ttft[tag] * 1e3:.1f},ms,"
            f"prompt={long_len} arriving mid-decode",
        ]
    rows += [
        f"serve_chunked_ttft_speedup,"
        f"{long_ttft['bucketed'] / long_ttft['chunked']:.2f},ratio,"
        f"bucketed/chunked long-prompt TTFT (in-band bucket compile "
        f"vs two pre-compiled unified shapes)",
        f"serve_chunked_vs_bucketed_tok_ratio,{tok_ratio:.2f},ratio,"
        f"chunked/bucketed continuous decode tok/s (1.0 = equal)",
    ]
    return rows


def run(full: bool = False, smoke: bool = False) -> list[str]:
    n_requests, slots = (32, 8) if smoke else (64, 8)
    gen_max = 64          # the skewed 4..64 workload from the acceptance spec
    page_size = 16
    max_len = 32 + gen_max
    cfg = smoke_config("qwen2-1.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    workload = synth_workload(
        n_requests, cfg.vocab, seed=0, prompt_lens=(8, 32),
        gen_lens=(4, gen_max), short_frac=0.8, rate=0.0)
    dense_cfg = ServeConfig(n_slots=slots, max_len=max_len,
                            max_prefill_batch=4)
    paged_cfg = ServeConfig(n_slots=slots, max_len=max_len,
                            max_prefill_batch=4, paged=True,
                            page_size=page_size)
    dense = _run_pair(cfg, params, workload, dense_cfg)
    paged = _run_pair(cfg, params, workload, paged_cfg)
    # continuous-vs-static parity is pinned inside each pair; pin the
    # dense-vs-paged layouts against each other too
    for uid, toks in dense["continuous"].outputs.items():
        assert paged["continuous"].outputs[uid] == toks, \
            f"dense/paged divergence on request {uid}"
    s, c, p = dense["static"], dense["continuous"], paged["continuous"]

    rows = []
    for tag, rep in (("static", s), ("continuous", c), ("paged", p)):
        rows += [
            f"serve_{tag}_decode_tok_s,{rep.decode_tok_s:.1f},tok_s,"
            f"decode_s={rep.decode_s:.3f};steps={rep.decode_steps}",
            f"serve_{tag}_prefill_tok_s,{rep.prefill_tok_s:.1f},tok_s,"
            f"prefill_s={rep.prefill_s:.3f};compile_s={rep.compile_s:.2f}",
            f"serve_{tag}_latency_p50_ms,{rep.latency_p50_s * 1e3:.1f},ms,"
            f"p99_ms={rep.latency_p99_s * 1e3:.1f}",
            f"serve_{tag}_occupancy,{rep.mean_occupancy:.3f},frac,"
            f"slots={slots};requests={n_requests}",
        ]
    speedup = c.decode_tok_s / s.decode_tok_s if s.decode_tok_s else 0.0
    rows.append(
        f"serve_speedup_decode,{speedup:.2f},ratio,"
        f"continuous/static decode tok/s on skewed gen 4..{gen_max} "
        f"({n_requests} reqs, {slots} slots)")

    # ---- dense vs paged memory accounting (HBM bytes one request pins) ----
    dense_req = slot_hbm_bytes(cfg, max_len)
    paged_req = slot_hbm_bytes(
        cfg, max_len, kv_rows=int(p.mean_pages_per_req * page_size))
    assert paged_req <= dense_req, (paged_req, dense_req)
    ratio = p.decode_tok_s / c.decode_tok_s if c.decode_tok_s else 0.0
    rows += [
        f"serve_dense_hbm_per_req_kb,{dense_req / 1024:.1f},kb,"
        f"max_len={max_len} rows per global layer",
        f"serve_paged_hbm_per_req_kb,{paged_req / 1024:.1f},kb,"
        f"mean_pages={p.mean_pages_per_req:.2f};page_size={page_size};"
        f"saving={1.0 - paged_req / dense_req:.2f}",
        f"serve_paged_page_occupancy,{p.mean_page_occupancy:.3f},frac,"
        f"pool={p.n_pages} pages",
        f"serve_paged_vs_dense_tok_ratio,{ratio:.2f},ratio,"
        f"paged/dense continuous decode tok/s (1.0 = equal)",
    ]

    # ---- chunked vs bucketed prefill: TTFT under a mid-decode long prompt --
    rows += _chunked_vs_bucketed(cfg, params)
    return rows


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r)
