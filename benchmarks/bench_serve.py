"""Serve-path A/B benchmark: static fixed-batch vs continuous-batching decode
on a skewed-length workload (short requests pay for the longest one in a
static batch; continuous retires and backfills slots independently).

Rows follow the orchestrator's ``name,value,derived`` convention; every
``serve_*`` row is also persisted to ``BENCH_serve.json`` by benchmarks/run.py
so successive PRs accumulate a serving-perf trajectory.
"""
from __future__ import annotations

import copy

import jax

from repro.configs import smoke_config
from repro.models.lm import init_lm
from repro.serve import ServeConfig, ServeEngine, synth_workload


def _run_pair(cfg, params, workload, scfg):
    reports = {}
    for engine in ("static", "continuous"):
        reqs = [copy.deepcopy(r) for r in workload]
        reports[engine] = ServeEngine(cfg, params, scfg, engine=engine).run(reqs)
    # greedy outputs must be token-identical across the two engines —
    # a perf number from diverging outputs would be meaningless
    if scfg.temperature <= 0.0:
        for uid, toks in reports["static"].outputs.items():
            assert reports["continuous"].outputs[uid] == toks, \
                f"static/continuous divergence on request {uid}"
    return reports


def run(full: bool = False, smoke: bool = False) -> list[str]:
    n_requests, slots = (32, 8) if smoke else (64, 8)
    gen_max = 64          # the skewed 4..64 workload from the acceptance spec
    cfg = smoke_config("qwen2-1.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    workload = synth_workload(
        n_requests, cfg.vocab, seed=0, prompt_lens=(8, 32),
        gen_lens=(4, gen_max), short_frac=0.8, rate=0.0)
    scfg = ServeConfig(n_slots=slots, max_len=32 + gen_max,
                       max_prefill_batch=4)
    reports = _run_pair(cfg, params, workload, scfg)
    s, c = reports["static"], reports["continuous"]

    rows = []
    for tag, rep in (("static", s), ("continuous", c)):
        rows += [
            f"serve_{tag}_decode_tok_s,{rep.decode_tok_s:.1f},"
            f"decode_s={rep.decode_s:.3f};steps={rep.decode_steps}",
            f"serve_{tag}_prefill_tok_s,{rep.prefill_tok_s:.1f},"
            f"prefill_s={rep.prefill_s:.3f};compile_s={rep.compile_s:.2f}",
            f"serve_{tag}_latency_p50_ms,{rep.latency_p50_s * 1e3:.1f},"
            f"p99_ms={rep.latency_p99_s * 1e3:.1f}",
            f"serve_{tag}_occupancy,{rep.mean_occupancy:.3f},"
            f"slots={slots};requests={n_requests}",
        ]
    speedup = c.decode_tok_s / s.decode_tok_s if s.decode_tok_s else 0.0
    rows.append(
        f"serve_speedup_decode,{speedup:.2f},"
        f"continuous/static decode tok/s on skewed gen 4..{gen_max} "
        f"({n_requests} reqs, {slots} slots)")
    return rows


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r)
