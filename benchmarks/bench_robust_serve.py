"""Byzantine-tolerant replicated serving benchmark (repro.serve.replicated).

Three panels on one shared greedy workload:

- honest baseline — single-replica ServeEngine vs the R-replica honest
  fleet: the voted stream is asserted TOKEN-IDENTICAL before any number is
  reported, and the replication overhead lands as the voted/single decode
  tok/s ratio (the price of fault tolerance when nothing faults);
- attack accuracy — for every inference-time attack (corrupt, sign_flip,
  little, empire) with f < R/2 Byzantine replicas, plus a dead and a
  hanging replica scenario: per-token accuracy of the voted stream against
  the honest stream (1.0 = robust vote fully masks the fault);
- quarantine latency — decode steps until the Zeno++-style pre-vote gate
  first evicts a Byzantine replica, and the fraction of its votes that
  scored divergent (the graceful-degradation reaction time in tokens).

Rows follow the orchestrator's ``name,value,unit,derived`` convention
(units here: ``tok_s``, ``ratio``, ``frac``, ``steps`` — accuracies are no
longer persisted as microseconds); every ``robustserve_*`` row is persisted
to ``BENCH_robust_serve.json`` by benchmarks/run.py so successive PRs
accumulate a robustness trajectory.
"""
from __future__ import annotations

import copy

import jax

from repro.configs import smoke_config
from repro.core.attacks import LogitAttackConfig
from repro.models.lm import init_lm
from repro.serve import (ReplicatedConfig, ReplicatedServeEngine, ServeConfig,
                         ServeEngine, synth_workload)

ATTACK_PANEL = ("corrupt", "sign_flip", "little", "empire")


def _accuracy(outputs, ref) -> float:
    """Per-token accuracy of ``outputs`` against the honest ``ref`` streams."""
    match = total = 0
    for uid, toks in ref.items():
        got = outputs.get(uid, [])
        total += len(toks)
        match += sum(1 for a, b in zip(got, toks) if a == b)
    return match / total if total else 0.0


def run(full: bool = False, smoke: bool = False) -> list[str]:
    n_requests = 8 if smoke else 24
    R, slots, gen_max = 3, 4, 16 if smoke else 32
    cfg = smoke_config("qwen2-1.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    max_len = 16 + gen_max
    scfg = ServeConfig(n_slots=slots, max_len=max_len, max_prefill_batch=2)
    workload = synth_workload(n_requests, cfg.vocab, seed=0,
                              prompt_lens=(4, 16), gen_lens=(4, gen_max),
                              short_frac=0.8, rate=0.0)

    def fresh():
        return [copy.deepcopy(r) for r in workload]

    def replicated(rcfg):
        return ReplicatedServeEngine(cfg, params, scfg, rcfg).run(fresh())

    # ---- honest baseline: single engine vs R-replica honest fleet --------
    single = ServeEngine(cfg, params, scfg).run(fresh())
    voted = replicated(ReplicatedConfig(n_replicas=R))
    assert voted.outputs == single.outputs, \
        "honest-fresh replicated stream diverged from the single engine"
    overhead = (voted.decode_tok_s / single.decode_tok_s
                if single.decode_tok_s else 0.0)
    rows = [
        f"robustserve_single_decode_tok_s,{single.decode_tok_s:.1f},tok_s,"
        f"decode_s={single.decode_s:.3f};steps={single.decode_steps}",
        f"robustserve_honest_decode_tok_s,{voted.decode_tok_s:.1f},tok_s,"
        f"R={R};vote={voted.vote};token_identical=1",
        f"robustserve_replication_tok_ratio,{overhead:.3f},ratio,"
        f"voted/single decode tok/s (fault-tolerance overhead, R={R})",
    ]

    # ---- per-attack accuracy vs the honest stream + quarantine latency ---
    scenarios = [(a, ReplicatedConfig(
        n_replicas=R, byz=(R - 1,), attack=LogitAttackConfig(name=a)))
        for a in ATTACK_PANEL]
    scenarios += [
        ("dead", ReplicatedConfig(n_replicas=R, dead=(R - 1,), dead_after=1)),
        ("hang", ReplicatedConfig(n_replicas=R, hang=(R - 1,))),
    ]
    for name, rcfg in scenarios:
        rep = replicated(rcfg)
        acc = _accuracy(rep.outputs, single.outputs)
        faulty = rep.replicas[R - 1]
        div = (faulty["divergent_tokens"] / faulty["tokens_voted"]
               if faulty["tokens_voted"] else 0.0)
        rows.append(
            f"robustserve_{name}_accuracy,{acc:.4f},frac,"
            f"f=1/{R};decode_tok_s={rep.decode_tok_s:.1f};"
            f"divergent_frac={div:.2f}")
        if rep.first_quarantine_step is not None:
            rows.append(
                f"robustserve_{name}_quarantine_tokens,"
                f"{rep.first_quarantine_step},steps,"
                f"decode steps to first eviction;"
                f"evictions={faulty['evictions']}")
    return rows


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r)
