"""Table 1 / Remark 4.1: wall-clock cost of the weighted aggregation rules —
all are O(dm) (+ log factors), so µs/call should scale ~linearly in d·m.
Also benchmarks the Pallas kernels (interpret mode) against the jnp oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import make_aggregator
from repro.utils import timeit_median

from .common import fmt_row

GRID = [(9, 10_000), (17, 100_000), (33, 1_000_000)]
SPECS = ("mean", "cwmed", "gm", "cwtm", "ctma:cwmed", "ctma:gm", "krum", "bucketing:cwmed")


def run(full: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    grid = GRID if full else GRID[:2]
    for m, d in grid:
        k1, k2 = jax.random.split(jax.random.fold_in(key, d))
        x = jax.random.normal(k1, (m, d))
        s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
        for spec in SPECS:
            agg = jax.jit(make_aggregator(spec, lam=0.25))
            us = timeit_median(lambda: agg(x, s), iters=5, warmup=2) * 1e6
            rows.append(fmt_row(f"aggcost_{spec}_m{m}_d{d}", us,
                                f"bytes_per_call={m * d * 4}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
