"""Table 1 / Remark 4.1: wall-clock cost of the weighted aggregation rules —
all are O(dm) (+ log factors), so µs/call should scale ~linearly in d·m.

Also benchmarks the Pallas kernel paths (interpret mode on CPU; Mosaic on
TPU) against the jnp oracles, including the fused vs unfused ω-CTMA pipeline
— the fusion removes one full HBM pass over the (m, d) matrix (3 -> 2), so
``aggpallas_ctma:cwmed_fused_speedup_*`` rows track the bandwidth win across
PRs via BENCH_agg.json (written by benchmarks/run.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.agg import resolve
from repro.kernels import ops
from repro.utils import timeit_median

from .common import fmt_row

GRID = [(9, 10_000), (17, 100_000), (33, 1_000_000)]
SPECS = ("mean", "cwmed", "gm", "cwtm", "ctma:cwmed", "ctma:gm", "krum", "bucketing:cwmed")

# Pallas-vs-oracle comparison grid: must include (17, 100_000) — the
# acceptance shape for the fused-CTMA speedup trajectory.
PALLAS_GRID = [(9, 10_000), (17, 100_000)]
PALLAS_SPECS = ("cwmed", "gm", "ctma:cwmed")


def _data(key, m, d):
    k1, k2 = jax.random.split(jax.random.fold_in(key, d + m))
    x = jax.random.normal(k1, (m, d))
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    return x, s


def run(full: bool = False, smoke: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    grid = GRID if full else GRID[:2]
    iters, warmup = (2, 1) if smoke else (5, 2)
    # Mosaic on TPU, interpreter elsewhere — otherwise the persisted
    # trajectory would time the interpreter on the hardware fusion targets.
    interp = jax.default_backend() != "tpu"

    # --- jnp aggregator scaling (Table 1 / Remark 4.1) ---------------------
    specs = SPECS[:2] if smoke else SPECS
    for m, d in (grid[:1] if smoke else grid):
        x, s = _data(key, m, d)
        for spec in specs:
            agg = jax.jit(resolve(spec, lam=0.25, backend="jnp"))
            us = timeit_median(lambda: agg(x, s), iters=iters, warmup=warmup) * 1e6
            rows.append(fmt_row(f"aggcost_{spec}_m{m}_d{d}", us,
                                f"bytes_per_call={m * d * 4}"))

    # --- Pallas kernels vs jnp oracles (both smoke and full keep the full
    # PALLAS_GRID: it ends at the acceptance shape m=17, d=100k) ------------
    for m, d in PALLAS_GRID:
        x, s = _data(key, m, d)
        for spec in PALLAS_SPECS:
            oracle = jax.jit(resolve(spec, lam=0.25, backend="jnp"))
            kern = resolve(spec, lam=0.25, backend="pallas", interpret=interp)
            us_o = timeit_median(lambda: oracle(x, s), iters=iters, warmup=warmup) * 1e6
            us_k = timeit_median(lambda: kern(x, s), iters=iters, warmup=warmup) * 1e6
            rows.append(fmt_row(f"aggpallas_{spec}_jnp_m{m}_d{d}", us_o,
                                f"bytes_per_call={m * d * 4}"))
            rows.append(fmt_row(f"aggpallas_{spec}_kernel_m{m}_d{d}", us_k,
                                f"vs_jnp_ratio={us_o / max(us_k, 1e-9):.3f}"))

        # fused vs unfused ω-CTMA: the tentpole fusion (2 vs >=3 HBM passes)
        fused = jax.jit(lambda x, s: ops.wctma(x, s, lam=0.25, fused=True,
                                               interpret=interp))
        unfused = jax.jit(lambda x, s: ops.wctma(x, s, lam=0.25, fused=False,
                                                 interpret=interp))
        us_f = timeit_median(lambda: fused(x, s), iters=iters, warmup=warmup) * 1e6
        us_u = timeit_median(lambda: unfused(x, s), iters=iters, warmup=warmup) * 1e6
        rows.append(fmt_row(f"aggpallas_ctma:cwmed_fused_m{m}_d{d}", us_f,
                            "hbm_passes=2"))
        rows.append(fmt_row(f"aggpallas_ctma:cwmed_unfused_m{m}_d{d}", us_u,
                            "hbm_passes=3"))
        rows.append(fmt_row(f"aggpallas_ctma:cwmed_fused_speedup_m{m}_d{d}",
                            us_u - us_f, f"speedup={us_u / max(us_f, 1e-9):.3f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
