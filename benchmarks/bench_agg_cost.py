"""Table 1 / Remark 4.1: wall-clock cost of the weighted aggregation rules —
all are O(dm) (+ log factors), so µs/call should scale ~linearly in d·m.

Also benchmarks the Pallas kernel paths (interpret mode on CPU; Mosaic on
TPU) against the jnp oracles, including the fused vs unfused ω-CTMA pipeline
— the fusion removes one full HBM pass over the (m, d) matrix (3 -> 2), so
``aggpallas_ctma:cwmed_fused_speedup_*`` rows track the bandwidth win across
PRs via BENCH_agg.json (written by benchmarks/run.py).

``run_hier`` (the ``agghier`` bench in benchmarks/run.py) times the
hierarchical cross-pod path (dist/hierarchy.py) against the single-host
stacked path on a 2-pod host mesh and records its collective-bytes / HBM
accounting from the compiled HLO: all-gather must stay 0 — the distance
reductions communicate only (m,)-sized partials over the pod axis. Needs
multiple host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in a fresh process); with a single device it emits nothing.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.agg import resolve
from repro.kernels import ops
from repro.utils import timeit_median

from .common import fmt_row

GRID = [(9, 10_000), (17, 100_000), (33, 1_000_000)]
SPECS = ("mean", "cwmed", "gm", "cwtm", "ctma:cwmed", "ctma:gm", "krum", "bucketing:cwmed")

# Pallas-vs-oracle comparison grid: must include (17, 100_000) — the
# acceptance shape for the fused-CTMA speedup trajectory.
PALLAS_GRID = [(9, 10_000), (17, 100_000)]
PALLAS_SPECS = ("cwmed", "gm", "ctma:cwmed")


def _data(key, m, d):
    k1, k2 = jax.random.split(jax.random.fold_in(key, d + m))
    x = jax.random.normal(k1, (m, d))
    s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
    return x, s


def run(full: bool = False, smoke: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    grid = GRID if full else GRID[:2]
    iters, warmup = (2, 1) if smoke else (5, 2)
    # Mosaic on TPU, interpreter elsewhere — otherwise the persisted
    # trajectory would time the interpreter on the hardware fusion targets.
    interp = jax.default_backend() != "tpu"

    # --- jnp aggregator scaling (Table 1 / Remark 4.1) ---------------------
    specs = SPECS[:2] if smoke else SPECS
    for m, d in (grid[:1] if smoke else grid):
        x, s = _data(key, m, d)
        for spec in specs:
            agg = jax.jit(resolve(spec, lam=0.25, backend="jnp"))
            us = timeit_median(lambda: agg(x, s), iters=iters, warmup=warmup) * 1e6
            rows.append(fmt_row(f"aggcost_{spec}_m{m}_d{d}", us,
                                f"bytes_per_call={m * d * 4}"))

    # --- Pallas kernels vs jnp oracles (both smoke and full keep the full
    # PALLAS_GRID: it ends at the acceptance shape m=17, d=100k) ------------
    for m, d in PALLAS_GRID:
        x, s = _data(key, m, d)
        for spec in PALLAS_SPECS:
            oracle = jax.jit(resolve(spec, lam=0.25, backend="jnp"))
            kern = resolve(spec, lam=0.25, backend="pallas", interpret=interp)
            us_o = timeit_median(lambda: oracle(x, s), iters=iters, warmup=warmup) * 1e6
            us_k = timeit_median(lambda: kern(x, s), iters=iters, warmup=warmup) * 1e6
            rows.append(fmt_row(f"aggpallas_{spec}_jnp_m{m}_d{d}", us_o,
                                f"bytes_per_call={m * d * 4}"))
            rows.append(fmt_row(f"aggpallas_{spec}_kernel_m{m}_d{d}", us_k,
                                f"vs_jnp_ratio={us_o / max(us_k, 1e-9):.3f}"))

        # fused vs unfused ω-CTMA: the tentpole fusion (2 vs >=3 HBM passes)
        fused = jax.jit(lambda x, s: ops.wctma(x, s, lam=0.25, fused=True,
                                               interpret=interp))
        unfused = jax.jit(lambda x, s: ops.wctma(x, s, lam=0.25, fused=False,
                                                 interpret=interp))
        us_f = timeit_median(lambda: fused(x, s), iters=iters, warmup=warmup) * 1e6
        us_u = timeit_median(lambda: unfused(x, s), iters=iters, warmup=warmup) * 1e6
        rows.append(fmt_row(f"aggpallas_ctma:cwmed_fused_m{m}_d{d}", us_f,
                            "hbm_passes=2"))
        rows.append(fmt_row(f"aggpallas_ctma:cwmed_unfused_m{m}_d{d}", us_u,
                            "hbm_passes=3"))
        rows.append(fmt_row(f"aggpallas_ctma:cwmed_fused_speedup_m{m}_d{d}",
                            us_u - us_f, f"speedup={us_u / max(us_f, 1e-9):.3f}x"))
    return rows


# ---------------------------------------------------------------------------
# Hierarchical cross-pod path (dist/hierarchy.py) — the ``agghier`` bench
# ---------------------------------------------------------------------------

HIER_GRID = [(9, 10_000), (17, 100_000)]
HIER_SPECS = (("ctma:cwmed", {"lam": 0.25}), ("gm", {"iters": 8}),
              ("krum", {"n_byz": 2}))


def _hier_tree(key, m, d):
    """(m, d) split into a two-leaf stacked tree with pod-divisible dims."""
    x, s = _data(key, m, d)
    return {"a": x[:, : d // 2], "b": x[:, d // 2:]}, s


def run_hier(full: bool = False, smoke: bool = False):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist.context import mesh_context
    from repro.dist.sharding import hier_momentum_sharding
    # NOT from repro.launch.dryrun — importing it would force the 512-device
    # placeholder platform via XLA_FLAGS before jax initializes
    from repro.utils import collective_bytes

    n_dev = jax.device_count()
    if n_dev < 4:
        print("# agghier: skipped — needs a multi-device host platform "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
              file=sys.stderr)
        return []
    mesh = jax.make_mesh((2, n_dev // 2), ("pod", "data"))
    rows = []
    key = jax.random.PRNGKey(1)
    iters, warmup = (2, 1) if smoke else (5, 2)
    grid = HIER_GRID[:1] if smoke else HIER_GRID
    specs = HIER_SPECS[:1] if smoke else HIER_SPECS
    for m, d in grid:
        tree, s = _hier_tree(key, m, d)
        for spec, kw in specs:
            stacked = jax.jit(resolve(f"{spec}@jnp", **kw))
            us_s = timeit_median(lambda: stacked(tree, s), iters=iters,
                                 warmup=warmup) * 1e6
            hier = resolve(spec, **kw)
            with mesh_context(mesh):
                jf = jax.jit(hier, in_shardings=(
                    hier_momentum_sharding(mesh, tree), NamedSharding(mesh, P())))
                # time the lowered executable directly — calling jf would
                # re-trace and re-compile (lower() does not seed jit's cache)
                compiled = jf.lower(tree, s).compile()
                us_h = timeit_median(lambda: compiled(tree, s), iters=iters,
                                     warmup=warmup) * 1e6
            coll = collective_bytes(compiled.as_text())
            try:
                ca = compiled.cost_analysis()
                ca = ca[0] if isinstance(ca, (list, tuple)) else ca
                hbm = int(float(ca.get("bytes accessed", 0.0)))
            except Exception:  # pragma: no cover
                hbm = 0
            rows.append(fmt_row(
                f"agghier_{spec}_m{m}_d{d}", us_h,
                f"vs_stacked_ratio={us_s / max(us_h, 1e-9):.3f};"
                f"allgather_B={coll['all-gather']};"
                f"allreduce_B={coll['all-reduce']};hbm_B={hbm};n_pod=2"))
            assert coll["all-gather"] == 0, (spec, coll)
    return rows


if __name__ == "__main__":
    print("\n".join(run() + run_hier()))
