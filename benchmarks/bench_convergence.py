"""Theorem 4.2 rate check: on a smooth convex quadratic with Byzantine
workers, the excess loss of Asynchronous Robust μ²-SGD decays ~1/√T — we
verify that quadrupling T roughly halves the excess loss (ratio in [1.3, 4]),
and that it decays at all under attack (the headline claim: diminishing error
with the number of honest updates).

Runs on the `repro.fleet` batched engine: every (T, seed) pair is one
Scenario, and since the horizon ``steps`` is NOT part of the compile
signature the whole (|Ts| × |seeds|) grid shares ONE jitted vmapped step —
the group runs to max(T) and snapshots each scenario at its own horizon.
"""
from __future__ import annotations

import numpy as np

from repro.fleet import Scenario, run_scenarios
from repro.optim import OptConfig

from .common import fmt_row

_OPT = OptConfig(name="mu2", lr=0.02, gamma=0.1, beta=0.25)


def _scenario(T: int, seed: int) -> Scenario:
    return Scenario(problem="quadratic", attack="sign_flip", agg="ctma:cwmed",
                    lam=0.38, m=9, byz_ids=(7, 8), arrival="proportional",
                    opt=_OPT, steps=T, batch=4, seed=seed)


def run(full: bool = False):
    Ts = (200, 800) if not full else (200, 800, 3200)
    seeds = (0, 1, 2)
    grid = [(T, s) for T in Ts for s in seeds]
    results = run_scenarios([_scenario(T, s) for T, s in grid])
    by_T = {T: [r.eval["excess"] for (t, _), r in zip(grid, results)
                if t == T] for T in Ts}
    excesses = [float(np.mean(by_T[T])) for T in Ts]
    us = results[0].us_per_step
    ratio = excesses[0] / max(excesses[1], 1e-12)
    return [fmt_row("thm42_rate", us,
                    ";".join(f"excess_T{t}={e:.4f}"
                             for t, e in zip(Ts, excesses))
                    + f";ratio_4xT={ratio:.2f}")]


if __name__ == "__main__":
    print("\n".join(run()))
