"""Theorem 4.2 rate check: on a smooth convex quadratic with Byzantine
workers, the excess loss of Asynchronous Robust μ²-SGD decays ~1/√T — we
verify that quadrupling T roughly halves the excess loss (ratio in [1.3, 4]),
and that it decays at all under attack (the headline claim: diminishing error
with the number of honest updates)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AsyncByzantineEngine, AttackConfig, EngineConfig
from repro.optim import OptConfig

from .common import fmt_row

D = 30
WSTAR = jnp.full((D,), 2.0)


def _excess(T, seed):
    def loss_fn(w, batch):
        return 0.5 * jnp.mean(jnp.sum((w - WSTAR - batch["x"]) ** 2, -1)) \
            + 0.0 * jnp.sum(batch["y"])

    cfg = EngineConfig(m=9, byz=(7, 8), attack=AttackConfig("sign_flip"),
                       agg="ctma:cwmed", lam=0.38, arrival="proportional",
                       opt=OptConfig(name="mu2", lr=0.02, gamma=0.1, beta=0.25),
                       seed=seed)
    eng = AsyncByzantineEngine(cfg, loss_fn, D)
    rng = np.random.default_rng(seed)
    init = {"x": jnp.asarray(rng.normal(size=(9, 4, D)), jnp.float32),
            "y": jnp.zeros((9, 4), jnp.int32)}
    st = eng.init(jnp.zeros((D,)), init)
    t0 = time.perf_counter()
    for _ in range(T):
        b = {"x": jnp.asarray(rng.normal(size=(4, D)), jnp.float32),
             "y": jnp.zeros((4,), jnp.int32)}
        st, _ = eng.step(st, b)
    dt = time.perf_counter() - t0
    # excess loss f(x_T) - f(x*) = 0.5||x_T - w*||² (+ const noise var)
    return 0.5 * float(jnp.sum((st.x - WSTAR) ** 2)), dt / T * 1e6


def run(full: bool = False):
    rows = []
    Ts = (200, 800) if not full else (200, 800, 3200)
    excesses = []
    us = 0.0
    for T in Ts:
        vals = [_excess(T, seed)[0] for seed in (0, 1, 2)]
        _, us = _excess(T, 0)
        excesses.append(float(np.mean(vals)))
    ratio = excesses[0] / max(excesses[1], 1e-12)
    rows.append(fmt_row("thm42_rate", us,
                        ";".join(f"excess_T{t}={e:.4f}" for t, e in zip(Ts, excesses))
                        + f";ratio_4xT={ratio:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
