"""The adversarial robustness matrix (``robust_*`` rows → BENCH_robust.json).

Runs the `repro.fleet` scenario grid — attack × aggregator spec × arrival
distribution × data heterogeneity — through the batched vmapped engine and
the breakdown-point bisection. Every cell reports its final loss against the
honest envelope, the smallest Byzantine mass that breaks it, and the resolved
aggregator's standalone µs/call.

The default grid is 4 attacks (sign_flip / little / empire + the
adaptive_scale attacker that tunes against the resolved rule) × 3 aggregator
specs (ω-CTMA over CWMed and GM, plus bare weighted CWMed) × 2 arrival
distributions × 2 heterogeneity levels (IID and Dirichlet α=0.3 label skew)
= 48 cells on the paper's MLP classifier. ``--smoke`` swaps in the quadratic
family at short horizons — same 48-cell grid, CI-sized.
"""
from __future__ import annotations

import math

from repro.fleet import breakdown_matrix, matrix_rows, matrix_scenarios

GRID = dict(
    attacks=("sign_flip", "little", "empire", "adaptive_scale"),
    aggs=("ctma:cwmed", "ctma:gm", "cwmed"),
    arrivals=("proportional", "squared"),
    alphas=(math.inf, 0.3),
    m=9, byz_frac=2.0 / 9.0, seeds=(0,),
    # coarser search keeps the adaptive attacker ~2x cheaper per step with
    # near-identical damage (the scale landscape is smooth in z)
    adaptive_params=(("gs_iters", 3), ("n_grid", 5)),
)


def run(full: bool = False, smoke: bool = False):
    if smoke:
        scenarios = matrix_scenarios(problem="quadratic", steps=60, batch=4,
                                     **GRID)
        bisect_steps = 30
    elif full:
        scenarios = matrix_scenarios(problem="classifier", steps=300, **GRID)
        bisect_steps = 100
    else:
        scenarios = matrix_scenarios(problem="classifier", steps=100, **GRID)
        bisect_steps = 40
    rows = breakdown_matrix(scenarios, bisect_steps=bisect_steps)
    return matrix_rows(rows)


if __name__ == "__main__":
    print("\n".join(run(smoke=True)))
