"""Kernel micro-benchmarks: Pallas (interpret mode — correctness-path timing
only on CPU; real perf is the TPU target) vs the jnp oracle, plus the robust
train-step throughput on the smoke configs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.utils import timeit_median

from .common import fmt_row


def run(full: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    for m, d in [(17, 100_000)] + ([(33, 1_000_000)] if full else []):
        k1, k2 = jax.random.split(jax.random.fold_in(key, d))
        x = jax.random.normal(k1, (m, d))
        s = jax.random.uniform(k2, (m,), minval=0.1, maxval=3.0)
        jit_cwmed_ref = jax.jit(ref.wcwmed_ref)
        jit_ctma_ref = jax.jit(lambda x, s: ref.wctma_ref(x, s, 0.25))
        for name, pallas_fn, ref_fn in [
            ("wcwmed", lambda: ops.wcwmed(x, s), lambda: jit_cwmed_ref(x, s)),
            ("wctma", lambda: ops.wctma(x, s, lam=0.25), lambda: jit_ctma_ref(x, s)),
        ]:
            us_ref = timeit_median(ref_fn, iters=3, warmup=1) * 1e6
            us_pal = timeit_median(pallas_fn, iters=3, warmup=1) * 1e6
            rows.append(fmt_row(f"kernel_{name}_m{m}_d{d}", us_pal,
                                f"jnp_oracle_us={us_ref:.1f};note=interpret-mode-on-CPU"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
