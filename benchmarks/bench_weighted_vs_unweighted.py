"""Paper Figure 2 / Figure 5: weighted vs non-weighted robust aggregators in
an imbalanced asynchronous Byzantine environment (arrivals ∝ id², so honest
fast workers dominate the update count; non-weighted rules treat them equally
with slow/Byzantine ones and lose accuracy)."""
from __future__ import annotations

from .common import fmt_row, run_async_experiment

# 17 workers / 8 Byzantine (paper Fig. 2), arrivals ∝ id². The Byzantine
# workers are the SLOW half: their *update mass* is tiny (λ_emp ≈ 0.11) but
# they are 8/17 ≈ 47% of the workers — unweighted rules treat their stale
# poisoned buffers as half the votes, weighted rules suppress them by s_i.
SETUP = dict(m=17, byz=(0, 1, 2, 3, 4, 5, 6, 7), arrival="squared", steps=500)


def run(full: bool = False):
    rows = []
    for attack, lam in (("label_flip", 0.3), ("sign_flip", 0.4)):
        for agg, label in (("cwmed", "CWMed"), ("gm", "RFA/GM")):
            accs = {}
            for weighted in (True, False):
                r = run_async_experiment(attack=attack, agg=agg, lam=lam,
                                         weighted=weighted, **SETUP)
                accs[weighted] = r
            name = f"fig2_{attack}_{label}"
            rows.append(fmt_row(name, accs[True]["us_per_step"],
                                f"acc_weighted={accs[True]['acc']:.3f};"
                                f"acc_unweighted={accs[False]['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
