"""Paper Figure 2 / Figure 5: weighted vs non-weighted robust aggregators in
an imbalanced asynchronous Byzantine environment (arrivals ∝ id², so honest
fast workers dominate the update count; non-weighted rules treat them equally
with slow/Byzantine ones and lose accuracy).

Runs on the `repro.fleet` batched engine: the weighted flag is a TRACED
argument of the vmapped step, so each (attack, aggregator) pair's
weighted/unweighted ablation runs as one two-scenario compile group.
"""
from __future__ import annotations

from repro.fleet import Scenario, run_scenarios

from .common import fmt_row

# 17 workers / 8 Byzantine (paper Fig. 2), arrivals ∝ id². The Byzantine
# workers are the SLOW half: their *update mass* is tiny (λ_emp ≈ 0.11) but
# they are 8/17 ≈ 47% of the workers — unweighted rules treat their stale
# poisoned buffers as half the votes, weighted rules suppress them by s_i.
SETUP = dict(problem="classifier", m=17, byz_ids=tuple(range(8)),
             arrival="squared", steps=500)


def run(full: bool = False, smoke: bool = False):
    rows = []
    setup = dict(SETUP, steps=200) if smoke else SETUP
    for attack, lam in (("label_flip", 0.3), ("sign_flip", 0.4)):
        for agg, label in (("cwmed", "CWMed"), ("gm", "RFA/GM")):
            pair = [Scenario(attack=attack, agg=agg, lam=lam,
                             weighted=w, **setup) for w in (True, False)]
            wt, unwt = run_scenarios(pair)
            rows.append(fmt_row(f"fig2_{attack}_{label}", wt.us_per_step,
                                f"acc_weighted={wt.eval['acc']:.3f};"
                                f"acc_unweighted={unwt.eval['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(smoke=True)))
