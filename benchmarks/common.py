"""Shared harness for the paper-reproduction benchmarks: trains the paper's
classifier with Alg. 2 under a configurable attack/aggregator and reports
test accuracy (the quantity plotted in the paper's figures)."""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import MLP_SMALL
from repro.core import AsyncByzantineEngine, AttackConfig, EngineConfig
from repro.data import classification_batches, make_classification_data, worker_batches
from repro.models.classifier import classifier_accuracy, classifier_loss, init_classifier
from repro.optim import OptConfig
from repro.utils import ravel_pytree_fn

MCFG = MLP_SMALL
# σ=1.6 keeps the Bayes accuracy high but leaves headroom so that broken
# training is visible as an accuracy gap (σ=0.8 saturates every variant at 1.0)
DATA_KW = dict(image_hw=MCFG.image_hw, channels=MCFG.channels, seed=0, sigma=1.6)


def run_async_experiment(
    *,
    attack: str = "sign_flip",
    agg: str = "ctma:cwmed",
    lam: float = 0.38,
    byz: tuple = (7, 8),
    m: int = 9,
    arrival: str = "proportional",
    opt: Optional[OptConfig] = None,
    steps: int = 500,
    batch: int = 8,
    seed: int = 0,
    weighted: bool = True,
) -> dict:
    """One training run; returns {'acc', 'us_per_step', 'final_loss'}."""
    opt = opt or OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25)
    params = init_classifier(jax.random.PRNGKey(seed), MCFG)
    flat, unravel = ravel_pytree_fn(params)

    def loss_fn(w, b):
        return classifier_loss(unravel(w), MCFG, b)

    ecfg = EngineConfig(m=m, byz=byz, attack=AttackConfig(attack), agg=agg,
                        lam=lam, arrival=arrival, opt=opt, seed=seed)
    eng = AsyncByzantineEngine(ecfg, loss_fn, flat.shape[0])
    if not weighted:  # ablation: ignore update counts (the non-weighted rules)
        inner = eng.agg_fn
        eng.agg_fn = lambda D, S: inner(D, jnp.ones_like(S))
        eng._step = jax.jit(eng._step_impl, donate_argnums=(0,))

    init = worker_batches(m, batch, **DATA_KW)
    st = eng.init(flat, {"x": jnp.asarray(init["x"]), "y": jnp.asarray(init["y"])})
    data = classification_batches(batch, **DATA_KW)

    # warmup-compile one step before timing
    b0 = next(data)
    st, _ = eng.step(st, {"x": jnp.asarray(b0["x"]), "y": jnp.asarray(b0["y"])})
    t0 = time.perf_counter()
    loss = np.nan
    for _ in range(steps):
        b = next(data)
        st, mtr = eng.step(st, {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])})
    jax.block_until_ready(st.x)
    dt = time.perf_counter() - t0

    test = make_classification_data(1024, sample_seed=10_000 + seed, **DATA_KW)
    acc = float(classifier_accuracy(unravel(st.x), MCFG,
                                    {"x": jnp.asarray(test["x"]),
                                     "y": jnp.asarray(test["y"])}))
    return {"acc": acc, "us_per_step": dt / steps * 1e6,
            "final_loss": float(mtr["loss"])}


def fmt_row(name: str, value: float, derived: str, unit: str = "us") -> str:
    """One orchestrator CSV row: ``name,value,unit,derived``.

    ``unit`` says what the value column measures (``us`` for per-call/step
    microseconds — the historical default — but also ``tok_s``, ``ms``,
    ``frac``, ``ratio``, ``kb``, ``steps`` for the serving and robustness
    panels whose headline numbers were never durations). benchmarks/run.py
    parses the unit back out and persists it next to the value, keeping
    ``us_per_call`` as a back-compat alias for ``us`` rows only."""
    return f"{name},{value:.1f},{unit},{derived}"
