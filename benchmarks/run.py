"""Benchmark orchestrator — one bench per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,fig3,...]

Prints ``name,us_per_call,derived`` CSV rows (stdout), mirroring the paper's
experimental panels:

    fig2_*      Fig. 2/5  weighted vs non-weighted robust aggregators
    fig3_*      Fig. 3/6  ω-CTMA effect on base aggregators
    fig4_*      Fig. 4/7  μ²-SGD vs momentum vs SGD
    thm42_*     Thm. 4.2  1/√T excess-loss decay under attack
    aggcost_*   Table 1 / Remark 4.1 aggregator cost scaling
    kernel_*    Pallas kernel timings (interpret mode)
    roofline_*  §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = {
    "aggcost": "benchmarks.bench_agg_cost",
    "fig2": "benchmarks.bench_weighted_vs_unweighted",
    "fig3": "benchmarks.bench_ctma_effect",
    "fig4": "benchmarks.bench_optimizers",
    "thm42": "benchmarks.bench_convergence",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.bench_roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(BENCHES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name = BENCHES[name]
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for row in mod.run(full=args.full):
                print(row, flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
