"""Benchmark orchestrator — one bench per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only fig2,...]

Prints ``name,value,unit,derived`` CSV rows (stdout) — ``unit`` names what
the value column measures (``us``, ``tok_s``, ``ms``, ``frac``, ``ratio``,
``kb``, ``steps``) — mirroring the paper's experimental panels:

    fig2_*      Fig. 2/5  weighted vs non-weighted robust aggregators
    fig3_*      Fig. 3/6  ω-CTMA effect on base aggregators
    fig4_*      Fig. 4/7  μ²-SGD vs momentum vs SGD
    thm42_*     Thm. 4.2  1/√T excess-loss decay under attack
    aggcost_*   Table 1 / Remark 4.1 aggregator cost scaling
    aggpallas_* Pallas kernel paths vs jnp oracles (fused vs unfused CTMA)
    agghier_*   hierarchical cross-pod path vs single-host stacked, with
                collective-bytes/HBM accounting (needs a multi-device host —
                run under XLA_FLAGS=--xla_force_host_platform_device_count=8)
    kernel_*    Pallas kernel timings (interpret mode)
    roofline_*  §Roofline terms from the dry-run artifacts
    serve_*     static vs continuous-batching decode A/B (tok/s, p50/p99
                latency, slot occupancy, decode speedup) — the value column
                carries the metric, not microseconds
    robustserve_* Byzantine-tolerant replicated decode: honest-baseline
                tok/s + replication overhead, per-attack token accuracy vs
                the honest stream, quarantine latency (value = metric)
    robust_*    repro.fleet adversarial robustness matrix — one row per
                attack × aggregator × arrival × heterogeneity cell; value =
                standalone aggregator µs/call, derived packs final loss vs
                the honest envelope + breakdown fraction (bisection over
                Byzantine mass on one compiled vmapped step)

Aggregation rows additionally persist to ``BENCH_agg.json`` at the repo root
so successive PRs accumulate a perf trajectory (``--smoke`` runs the reduced
aggcost + agghier grids only — the CI fast path — and still records the
fused-CTMA speedup at the acceptance shape m=17, d=100k). Serve rows persist
the same way to ``BENCH_serve.json`` (``--only serve --smoke`` is the CI
serve step), replicated-serving rows to ``BENCH_robust_serve.json``
(``--only robust-serve --smoke`` is the CI serving-robustness step), and
training-side robustness-matrix rows to ``BENCH_robust.json``
(``--only robust --smoke`` is the CI training-robustness step).
"""
from __future__ import annotations

import argparse
import inspect
import json
import re
import sys
import time
from pathlib import Path

BENCHES = {
    "aggcost": "benchmarks.bench_agg_cost",
    "agghier": "benchmarks.bench_agg_cost:run_hier",
    "fig2": "benchmarks.bench_weighted_vs_unweighted",
    "fig3": "benchmarks.bench_ctma_effect",
    "fig4": "benchmarks.bench_optimizers",
    "thm42": "benchmarks.bench_convergence",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.bench_roofline",
    "serve": "benchmarks.bench_serve",
    "robust-serve": "benchmarks.bench_robust_serve",
    "robust": "benchmarks.bench_robust",
}

BENCH_AGG_PATH = Path(__file__).resolve().parents[1] / "BENCH_agg.json"
BENCH_SERVE_PATH = Path(__file__).resolve().parents[1] / "BENCH_serve.json"
BENCH_ROBUST_SERVE_PATH = (Path(__file__).resolve().parents[1]
                           / "BENCH_robust_serve.json")
BENCH_ROBUST_PATH = Path(__file__).resolve().parents[1] / "BENCH_robust.json"


_UNIT_RE = re.compile(r"[A-Za-z][A-Za-z0-9_]*")


def _parse_row(row: str) -> dict:
    """Parse a bench row into its persisted dict.

    Canonical rows are 4-field ``name,value,unit,derived``; legacy 3-field
    ``name,value,derived`` rows (pre-unit writers) are still accepted with
    ``unit="us"``. The unit slot is only claimed when it looks like a bare
    unit token — legacy ``derived`` text can itself contain commas, so the
    discriminator is the field shape, not the comma count. ``us_per_call``
    is kept as a back-compat alias, but only for rows whose value really is
    microseconds — accuracy/ratio rows no longer masquerade as durations."""
    name, value, rest = row.split(",", 2)
    unit, sep, derived = rest.partition(",")
    if not (sep and _UNIT_RE.fullmatch(unit)):
        unit, derived = "us", rest
    out = {"name": name, "value": float(value), "unit": unit,
           "derived": derived}
    if unit == "us":
        out["us_per_call"] = out["value"]
    return out


def _persist(path: Path, prefixes: tuple, rows: list[str], tag: str) -> None:
    """Append matching rows to a trajectory file, keeping the last 20 runs."""
    matched = [_parse_row(r) for r in rows if r.startswith(prefixes)]
    if not matched:
        return
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text()).get("runs", [])
        except (json.JSONDecodeError, AttributeError):
            history = []
    history.append({"unix_time": int(time.time()), "rows": matched})
    path.write_text(json.dumps({"runs": history[-20:]}, indent=1))
    print(f"# wrote {len(matched)} {tag} rows to {path.name}", file=sys.stderr)


def persist_agg(rows: list[str]) -> None:
    """Append this run's aggregation rows to BENCH_agg.json (perf trajectory)."""
    _persist(BENCH_AGG_PATH, ("aggcost_", "aggpallas_", "agghier_"), rows, "agg")


def persist_serve(rows: list[str]) -> None:
    """Append this run's serve rows to BENCH_serve.json (tokens/s, p50/p99
    latency, slot occupancy, static-vs-continuous decode speedup)."""
    _persist(BENCH_SERVE_PATH, ("serve_",), rows, "serve")


def persist_robust_serve(rows: list[str]) -> None:
    """Append this run's replicated-serving rows to BENCH_robust_serve.json
    (honest-baseline tok/s + replication overhead, per-attack token accuracy
    vs the honest stream, quarantine latency in decode steps)."""
    _persist(BENCH_ROBUST_SERVE_PATH, ("robustserve_",), rows, "robust-serve")


def persist_robust(rows: list[str]) -> None:
    """Append this run's robustness-matrix rows to BENCH_robust.json — one
    cell per row: aggregator µs/call in the value column; final loss, honest
    envelope, breakdown fraction and engine step cost in ``derived``."""
    _persist(BENCH_ROBUST_PATH, ("robust_",), rows, "robust")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: reduced aggcost + agghier grids")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench name(s) {unknown}; choose from {list(BENCHES)}")
    if args.smoke and not args.only:
        names = ["aggcost", "agghier"]

    print("name,value,unit,derived")
    failures = 0
    all_rows: list[str] = []
    for name in names:
        mod_name, _, attr = BENCHES[name].partition(":")
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            fn = getattr(mod, attr or "run")
            if "smoke" in inspect.signature(fn).parameters:
                rows = fn(full=args.full, smoke=args.smoke)
            else:  # benches that predate the smoke flag
                rows = fn(full=args.full)
            for row in rows:
                print(row, flush=True)
            all_rows.extend(rows)
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    persist_agg(all_rows)
    persist_serve(all_rows)
    persist_robust_serve(all_rows)
    persist_robust(all_rows)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
