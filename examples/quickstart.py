"""Quickstart: the unified aggregator API (`repro.agg`) + a 60-second
asynchronous Byzantine training run on the paper's classifier.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import agg
from repro.core import AsyncByzantineEngine, AttackConfig, EngineConfig, expected_lambda
from repro.configs.paper_cnn import MLP_SMALL
from repro.data import classification_batches, make_classification_data, worker_batches
from repro.models.classifier import classifier_accuracy, classifier_loss, init_classifier
from repro.optim import OptConfig
from repro.utils import ravel_pytree_fn

# --- 1. one spec string, one resolve path, any layout -----------------------
# Spec grammar: rule[:base][@backend], e.g. "cwmed", "ctma:gm@pallas", "zeno".
key = jax.random.PRNGKey(0)
m, d = 9, 1000
honest = jax.random.normal(key, (m, d)) * 0.1 + 1.0
byzantine = honest.at[7:].set(-50.0)              # two corrupt workers
weights = jnp.arange(1.0, m + 1)                  # update counts s_i

print("weighted mean  (poisoned):", float(jnp.mean(byzantine @ jnp.ones(d))) / d)
# byz weight mass = (8+9)/45 ≈ 0.38, so the meta-aggregator needs λ ≥ 0.38
for spec in ("cwmed", "gm", "ctma:cwmed", "zeno"):
    rule = agg.resolve(spec, lam=0.4)             # layout-polymorphic callable
    out = rule(byzantine, weights)                # flat (m, d) matrix path
    print(f"{spec:12s} -> mean coordinate {float(jnp.mean(out)):+.3f} (honest ≈ +1.0)")

# the SAME resolved callable aggregates a stacked pytree (leaves (m, ...)),
# leaf-wise with one global distance pass — the dist.steps production layout
tree = {"w": byzantine[:, :900].reshape(m, 30, 30), "b": byzantine[:, 900:]}
out = agg.resolve("ctma:cwmed", lam=0.4)(tree, weights)
print(f"{'ctma (tree)':12s} -> mean coordinate "
      f"{float(jnp.mean(out['w'])):+.3f} (same rule, pytree layout)")

# --- 2. asynchronous Byzantine training (Algorithm 2) ------------------------
mcfg = MLP_SMALL
params = init_classifier(key, mcfg)
flat, unravel = ravel_pytree_fn(params)

ecfg = EngineConfig(
    m=9, byz=(7, 8), attack=AttackConfig("sign_flip"),
    agg="ctma:cwmed", lam=0.38, arrival="proportional",
    opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
print(f"\nAsync Byzantine run: m=9 workers, byz={ecfg.byz}, "
      f"expected λ={expected_lambda(ecfg):.2f}")

eng = AsyncByzantineEngine(
    ecfg, lambda w, b: classifier_loss(unravel(w), mcfg, b), flat.shape[0])
kw = dict(image_hw=mcfg.image_hw, channels=mcfg.channels, seed=0, sigma=0.8)
init = worker_batches(9, 8, **kw)
state = eng.init(flat, {"x": jnp.asarray(init["x"]), "y": jnp.asarray(init["y"])})
data = classification_batches(8, **kw)
for step in range(400):
    b = next(data)
    state, metrics = eng.step(state, {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])})
    if (step + 1) % 100 == 0:
        print(f"  step {step+1}: loss={float(metrics['loss']):.4f} "
              f"λ_emp={float(metrics['lambda_emp']):.2f}")

test = make_classification_data(512, sample_seed=123, **kw)
acc = classifier_accuracy(unravel(state.x), mcfg,
                          {"x": jnp.asarray(test["x"]), "y": jnp.asarray(test["y"])})
print(f"test accuracy under sign-flip attack: {float(acc):.3f}")
