"""Quickstart: weighted robust aggregation + a 60-second asynchronous
Byzantine training run on the paper's classifier.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (AsyncByzantineEngine, AttackConfig, EngineConfig,
                        expected_lambda, weighted_ctma, weighted_cwmed, weighted_gm)
from repro.configs.paper_cnn import MLP_SMALL
from repro.data import classification_batches, make_classification_data, worker_batches
from repro.models.classifier import classifier_accuracy, classifier_loss, init_classifier
from repro.optim import OptConfig
from repro.utils import ravel_pytree_fn

# --- 1. weighted robust aggregators on raw vectors --------------------------
key = jax.random.PRNGKey(0)
m, d = 9, 1000
honest = jax.random.normal(key, (m, d)) * 0.1 + 1.0
byzantine = honest.at[7:].set(-50.0)              # two corrupt workers
weights = jnp.arange(1.0, m + 1)                  # update counts s_i

print("weighted mean  (poisoned):", float(jnp.mean(byzantine @ jnp.ones(d))) / d)
# byz weight mass = (8+9)/45 ≈ 0.38, so the meta-aggregator needs λ ≥ 0.38
for name, agg in [("ω-CWMed", weighted_cwmed(byzantine, weights)),
                  ("ω-GM", weighted_gm(byzantine, weights)),
                  ("ω-CTMA", weighted_ctma(byzantine, weights, lam=0.4))]:
    print(f"{name:8s} -> mean coordinate {float(jnp.mean(agg)):+.3f} (honest ≈ +1.0)")

# --- 2. asynchronous Byzantine training (Algorithm 2) ------------------------
mcfg = MLP_SMALL
params = init_classifier(key, mcfg)
flat, unravel = ravel_pytree_fn(params)

ecfg = EngineConfig(
    m=9, byz=(7, 8), attack=AttackConfig("sign_flip"),
    agg="ctma:cwmed", lam=0.38, arrival="proportional",
    opt=OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25))
print(f"\nAsync Byzantine run: m=9 workers, byz={ecfg.byz}, "
      f"expected λ={expected_lambda(ecfg):.2f}")

eng = AsyncByzantineEngine(
    ecfg, lambda w, b: classifier_loss(unravel(w), mcfg, b), flat.shape[0])
kw = dict(image_hw=mcfg.image_hw, channels=mcfg.channels, seed=0, sigma=0.8)
init = worker_batches(9, 8, **kw)
state = eng.init(flat, {"x": jnp.asarray(init["x"]), "y": jnp.asarray(init["y"])})
data = classification_batches(8, **kw)
for step in range(400):
    b = next(data)
    state, metrics = eng.step(state, {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])})
    if (step + 1) % 100 == 0:
        print(f"  step {step+1}: loss={float(metrics['loss']):.4f} "
              f"λ_emp={float(metrics['lambda_emp']):.2f}")

test = make_classification_data(512, sample_seed=123, **kw)
acc = classifier_accuracy(unravel(state.x), mcfg,
                          {"x": jnp.asarray(test["x"]), "y": jnp.asarray(test["y"])})
print(f"test accuracy under sign-flip attack: {float(acc):.3f}")
