"""End-to-end driver: train a transformer LM for a few hundred steps with the
paper's Asynchronous Robust μ²-SGD, under an active Byzantine minority, and
compare against the undefended mean aggregator.

    PYTHONPATH=src python examples/train_async_robust.py [--steps 300]

The model is a reduced qwen2-family decoder (~3M params) on the synthetic
affine-recurrence LM task; 9 async workers with arrivals ∝ worker id, two
Byzantine workers mounting a sign-flip attack (λ ≈ 0.38).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import AsyncByzantineEngine, AttackConfig, EngineConfig, expected_lambda
from repro.data import lm_batches
from repro.models import init_lm, lm_loss
from repro.utils import logger
from repro.optim import OptConfig


def run(agg: str, lam: float, steps: int, seed: int = 0) -> list:
    cfg = smoke_config("qwen2-1.5b").with_(n_layers=2, d_model=128, d_ff=256,
                                           vocab=256)
    # PYTREE-NATIVE engine: the parameter tree goes in as-is — no O(d) ravel /
    # unravel round-trip per gradient; the stacked momentum buffers aggregate
    # leaf-wise through repro.agg with one global distance pass.
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    logger.info("model: %s (%.2fM params), agg=%s", cfg.name, n_params / 1e6, agg)

    def loss_fn(p, batch):
        return lm_loss(p, cfg, batch)

    ecfg = EngineConfig(m=9, byz=(7, 8), attack=AttackConfig("sign_flip"),
                        agg=agg, lam=lam, arrival="proportional",
                        opt=OptConfig(name="mu2", lr=0.02, gamma=0.1, beta=0.25),
                        seed=seed)
    logger.info("expected Byzantine update fraction λ=%.2f", expected_lambda(ecfg))
    eng = AsyncByzantineEngine(ecfg, loss_fn)

    data = lm_batches(cfg, 4, 64, seed=seed)

    def jb(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    m = 9
    init_stack = [next(data) for _ in range(m)]
    init_batches = {k: jnp.stack([jnp.asarray(b[k]) for b in init_stack])
                    for k in init_stack[0]}
    state = eng.init(params, init_batches)

    losses = []
    for k in range(steps):
        state, metrics = eng.step(state, jb(next(data)))
        losses.append(float(metrics["loss"]))
        if (k + 1) % 50 == 0:
            logger.info("  [%s] step %d loss %.4f", agg, k + 1,
                        float(np.mean(losses[-20:])))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    robust = run("ctma:cwmed", lam=0.38, steps=args.steps)
    undefended = run("mean", lam=0.0, steps=args.steps)

    r, u = np.mean(robust[-30:]), np.mean(undefended[-30:])
    logger.info("final loss — robust ω-CTMA: %.4f | undefended mean: %.4f", r, u)
    if r < u:
        logger.info("robust aggregation defended the run (lower is better)")


if __name__ == "__main__":
    main()
