"""Remark 3.1 in practice: synchronous data-parallel groups with UNEVEN batch
sizes, robust-aggregated with weights ∝ batch size — the weighted framework's
natural generalization beyond asynchrony. One group is Byzantine.

    PYTHONPATH=src python examples/heterogeneous_batches.py
"""
import jax
import numpy as np

from repro.data import lm_batches
from repro.dist.steps import RobustDPConfig, init_train_state, make_robust_train_step
from repro.models import ModelConfig
from repro.optim import OptConfig
from repro.utils import logger

import jax.numpy as jnp

CFG = ModelConfig(name="tiny-lm", n_layers=2, d_model=96, n_heads=4, n_kv=2,
                  d_ff=192, vocab=128)
OPT = OptConfig(name="mu2", lr=5e-3, gamma=0.1, beta=0.25)

for weight_mode in ("batch_size", "counts"):
    rcfg = RobustDPConfig(n_groups=4, agg="ctma:cwmed", lam=0.3,
                          weight_mode=weight_mode, group_sizes=(1, 2, 3, 2),
                          byz_groups=(0,), byz_attack="sign_flip")
    step = jax.jit(make_robust_train_step(CFG, OPT, rcfg))
    state = init_train_state(CFG, OPT, jax.random.PRNGKey(0), rcfg)
    data = lm_batches(CFG, 8, 48, seed=1)
    losses = []
    for _ in range(120):
        state, m = step(state, {k: jnp.asarray(v) for k, v in next(data).items()})
        losses.append(float(m["loss"]))
    logger.info("weights=%-11s first %.4f -> last %.4f (Byzantine group 0 active)",
                weight_mode, np.mean(losses[:10]), np.mean(losses[-10:]))
logger.info("weighting by contributed samples (Remark 3.1) integrates cleanly "
            "with the robust-DP train step")
