"""Serving example: prefill + batched greedy decode with every cache type
(ring-buffer sliding window, SSM state, RG-LRU) on reduced configs.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.dist.steps import make_prefill_step, make_serve_step
from repro.models import init_lm
from repro.utils import logger

ARCHS = ["qwen2-1.5b", "gemma3-4b", "mamba2-1.3b", "recurrentgemma-9b"]
B, PROMPT, GEN = 2, 32, 16

for arch in ARCHS:
    cfg = smoke_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(make_prefill_step(cfg, PROMPT + GEN))
    serve = jax.jit(make_serve_step(cfg))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, PROMPT)), jnp.int32)}

    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    toks = [tok]
    t0 = time.perf_counter()
    for _ in range(GEN - 1):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    logger.info("%-18s generated %s tokens/req (%5.1f tok/s): %s",
                arch, GEN, B * GEN / dt, np.asarray(out[0][:8]))
