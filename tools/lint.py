"""replint driver — JAX/Pallas-aware static analysis over the repro tree.

    python tools/lint.py src/repro                      # all groups, exit 1 on findings
    python tools/lint.py src/repro --report lint_report.json   # CI artifact
    python tools/lint.py --only docs                    # old docs_check behavior
    python tools/lint.py --only pallas --vmem-budget 8  # tighter kernel budget
    python tools/lint.py --write-kernel-table           # refresh kernels/README.md
    python tools/lint.py --check-kernel-table           # CI drift gate

Groups: ``ast`` (RL101–RL105 JAX hazards), ``pallas`` (RP301–RP303 kernel
VMEM/grid audit + generated VMEM table), ``docs`` (RD201/RD202, the folded
``tools/docs_check.py``). Rule catalog: ``tools/lint/README.md``.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from lint import (AST_RULES, DEFAULT_VMEM_BUDGET, GROUPS, audit_paths,
                  build_report, docs_findings, emit, iter_python_files,
                  lint_files, render_readme, vmem_table)
from lint.engine import REPO_ROOT, apply_suppressions

KERNELS_README = REPO_ROOT / "src" / "repro" / "kernels" / "README.md"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="lint.py", description=__doc__)
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--only", choices=GROUPS, action="append",
                    help="run only this rule group (repeatable)")
    ap.add_argument("--report", metavar="FILE",
                    help="write the JSON lint report here")
    ap.add_argument("--vmem-budget", type=float, default=None, metavar="MIB",
                    help=f"Pallas per-kernel VMEM budget in MiB "
                         f"(default {DEFAULT_VMEM_BUDGET / 2**20:.0f})")
    ap.add_argument("--write-kernel-table", action="store_true",
                    help="regenerate the VMEM table in kernels/README.md")
    ap.add_argument("--check-kernel-table", action="store_true",
                    help="fail if the kernels/README.md VMEM table is stale")
    args = ap.parse_args(argv)

    groups = tuple(args.only) if args.only else GROUPS
    paths = [Path(p) for p in args.paths] or [REPO_ROOT / "src" / "repro"]
    budget = int(args.vmem_budget * 2**20) if args.vmem_budget \
        else DEFAULT_VMEM_BUDGET

    files = iter_python_files(paths)
    active, suppressed, sups = [], [], []
    extra = {}

    if "ast" in groups:
        a, s, sp = lint_files(files, AST_RULES)
        active += a
        suppressed += s
        sups += sp

    if "pallas" in groups:
        sites, pf = audit_paths(paths, budget)
        # pallas findings honor the same line-level suppressions
        pa, ps = apply_suppressions(pf, sups)
        # drop RL000 duplicates re-raised by the second apply pass
        pa = [f for f in pa if f.code != "RL000"]
        active += pa
        suppressed += ps
        extra["kernels"] = [{
            "path": s.path, "line": s.line, "kernel": s.func,
            "grid": s.grid_src, "vmem_bytes": s.vmem_bytes,
            "assumed": s.assumed,
        } for s in sorted(sites, key=lambda s: (s.path, s.line))]
        # per-file rollup over the whole kernels package, zero-site files
        # included, so the report accounts for every kernel file
        kdir = REPO_ROOT / "src" / "repro" / "kernels"
        by_file = {}
        for s in sites:
            by_file.setdefault(s.path.rsplit("/", 1)[-1], []).append(s)
        extra["kernel_files"] = [{
            "file": p.name,
            "sites": len(by_file.get(p.name, [])),
            "max_vmem_bytes": max((s.vmem_bytes
                                   for s in by_file.get(p.name, [])),
                                  default=0),
        } for p in sorted(kdir.glob("*.py")) if p.name != "__init__.py"]

        table = vmem_table(sites, budget)
        if args.write_kernel_table or args.check_kernel_table:
            current = KERNELS_README.read_text() \
                if KERNELS_README.exists() else ""
            desired = render_readme(current, table)
            if args.check_kernel_table and desired != current:
                from lint.engine import Finding
                active.append(Finding(
                    "RP300", "src/repro/kernels/README.md", 1,
                    "VMEM table is stale — regenerate with "
                    "'python tools/lint.py --write-kernel-table'"))
            if args.write_kernel_table and desired != current:
                KERNELS_README.write_text(desired)
                print(f"updated {KERNELS_README.relative_to(REPO_ROOT)}")

    if "docs" in groups:
        active += docs_findings()

    active.sort(key=lambda f: (f.path, f.line, f.code))
    report = build_report(active, suppressed, sups, groups=list(groups),
                          files=files, extra=extra)
    return emit(report, args.report)


if __name__ == "__main__":
    raise SystemExit(main())
