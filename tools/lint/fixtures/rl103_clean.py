"""RL103 clean twin: data-dependent selection stays inside the trace."""
import jax
import jax.numpy as jnp


@jax.jit
def clip_if_large(x):
    big = jnp.max(jnp.abs(x)) > 1e3
    return jnp.where(big, jnp.clip(x, -1e3, 1e3), x)


def host_side(x):
    # not jitted: a Python branch on a concrete array is fine here
    if jnp.max(jnp.abs(x)) > 1e3:
        return jnp.clip(x, -1e3, 1e3)
    return x
