"""RL105 clean twin: the .at[] update result is assigned."""
import jax.numpy as jnp


def zero_row(x, i):
    x = x.at[i].set(0.0)
    return x
