"""RL102 bad fixture: jit params steer Python control flow without being
static."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def branchy(x, mode):
    if mode:                      # BAD: `mode` is traced, branch is Python
        return x * 2.0
    return x


@functools.partial(jax.jit, static_argnames=("depth",))
def loopy(x, depth, iters):
    for _ in range(iters):        # BAD: `iters` not in static_argnames
        x = x + 1.0
    for _ in range(depth):        # fine: depth is static
        x = x * 0.5
    return jnp.tanh(x)
