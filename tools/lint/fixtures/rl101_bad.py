"""RL101 bad fixture: donated buffer read after the donating call."""
import jax


def step(state, x):
    return state + x, x


class Engine:
    def __init__(self):
        self._step = jax.jit(step, donate_argnums=(0,))
        self.state = None

    def run_use_after_donate(self, state, x):
        new_state, tok = self._step(state, x)   # donates `state`
        return state + tok                      # BAD: reads the dead buffer

    def run_loop_no_rebind(self, state, xs):
        outs = []
        for x in xs:
            out, _ = self._step(state, x)       # BAD: donated, reused next iter
            outs.append(out)
        return outs
