"""RP303 clean twin: pools reserve the dump page the block table targets."""
import jax.numpy as jnp
import numpy as np


def init_pool(n_pages, page_size, kv, hd, n_slots, pages_per_slot):
    table = np.full((n_slots + 1, pages_per_slot), n_pages, np.int32)
    k_pool = jnp.zeros((n_pages + 1, page_size, kv, hd), jnp.float32)
    v_pool = jnp.zeros((n_pages + 1, page_size, kv, hd), jnp.float32)
    return k_pool, v_pool, table
