"""RL104 clean twin: seeded generators; clocks only on the host side."""
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_batch(n, seed=0):
    rng = np.random.default_rng(seed)     # fine: explicit seeded generator
    return rng.normal(size=(n, 4))


@jax.jit
def stamped(x, t):
    return x + t                          # timestamp passed in as data


def timed_call(x):
    t0 = time.time()                      # fine: host-side timing
    y = stamped(x, jnp.float32(0.0))
    return y, time.time() - t0
