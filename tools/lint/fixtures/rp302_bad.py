"""RP302 bad fixture: index-map arity disagrees with the grid rank."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N = 512
TILE = 128


def copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_arity(x):
    return pl.pallas_call(
        copy_kernel,
        grid=(N // TILE, N // TILE),                      # rank 2
        in_specs=[pl.BlockSpec((TILE, TILE), lambda i: (i, 0))],   # 1 arg
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i,)),   # 1 index
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
    )(x)
