"""RP303 bad fixture: page pool allocated without the reserved dump page."""
import jax.numpy as jnp
import numpy as np


def init_pool(n_pages, page_size, kv, hd, n_slots, pages_per_slot):
    # block table points unallocated pages at index n_pages ...
    table = np.full((n_slots + 1, pages_per_slot), n_pages, np.int32)
    # ... but the pool has no physical page n_pages: out-of-bounds gather
    k_pool = jnp.zeros((n_pages, page_size, kv, hd), jnp.float32)  # BAD
    v_pool = jnp.zeros((n_pages, page_size, kv, hd), jnp.float32)  # BAD
    return k_pool, v_pool, table
