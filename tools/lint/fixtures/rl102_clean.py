"""RL102 clean twin: control-flow-steering params are static (or the use is
trace-safe: shape/None checks)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode",))
def branchy(x, mode):
    if mode:                      # fine: mode is static
        return x * 2.0
    return x


@functools.partial(jax.jit, static_argnames=("iters",))
def loopy(x, iters, scale=None):
    if scale is None:             # fine: `is None` dispatch is trace-safe
        scale = 1.0
    for _ in range(iters):        # fine: iters is static
        x = x + scale
    for _ in range(x.ndim):       # fine: shapes are static under tracing
        x = jnp.expand_dims(x, 0)
    return x
