"""RP301 bad fixture: one block blows the 16 MiB VMEM budget."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HUGE = 4096


def copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def huge_block(x):
    # (4096, 4096) f32 in + out = 128 MiB resident — way over budget
    return pl.pallas_call(
        copy_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((HUGE, HUGE), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((HUGE, HUGE), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((HUGE, HUGE), jnp.float32),
    )(x)
