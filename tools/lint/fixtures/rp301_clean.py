"""RP301 clean twin: the same copy tiled down to a VMEM-sized block."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HUGE = 4096
TILE = 512


def copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def tiled_copy(x):
    # (512, 512) f32 in + out = 2 MiB resident — fits comfortably
    return pl.pallas_call(
        copy_kernel,
        grid=(HUGE // TILE, HUGE // TILE),
        in_specs=[pl.BlockSpec((TILE, TILE), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((HUGE, HUGE), jnp.float32),
    )(x)
