"""RL101 clean twin: donated buffers are always rebound before reuse."""
import jax


def step(state, x):
    return state + x, x


class Engine:
    def __init__(self):
        self._step = jax.jit(step, donate_argnums=(0,))
        self.state = None

    def run(self, state, x):
        state, tok = self._step(state, x)       # result rebinds the donation
        return state + tok

    def run_loop(self, state, xs):
        outs = []
        for x in xs:
            state, out = self._step(state, x)   # rebound every iteration
            outs.append(out)
        return state, outs

    def run_attr(self, x):
        self.state = self._step(self.state, x)[0]   # attr path rebound
        return self.state
