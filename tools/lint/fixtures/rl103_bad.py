"""RL103 bad fixture: Python branch on a traced value inside a jitted fn."""
import jax
import jax.numpy as jnp


@jax.jit
def clip_if_large(x):
    if jnp.max(jnp.abs(x)) > 1e3:     # BAD: TracerBoolConversionError
        return jnp.clip(x, -1e3, 1e3)
    return x
