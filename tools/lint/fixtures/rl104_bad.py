"""RL104 bad fixture: unseeded global-RNG draws and a host clock in jit."""
import time

import jax
import numpy as np


def make_batch(n):
    return np.random.randn(n, 4)      # BAD: unseeded global RNG


@jax.jit
def stamped(x):
    t = time.time()                   # BAD: baked in at trace time
    return x + t
