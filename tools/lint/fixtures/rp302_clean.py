"""RP302 clean twin: index maps take one arg per grid axis and return one
index per block axis."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N = 512
TILE = 128


def copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def good_arity(x):
    return pl.pallas_call(
        copy_kernel,
        grid=(N // TILE, N // TILE),
        in_specs=[pl.BlockSpec((TILE, TILE), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
    )(x)
