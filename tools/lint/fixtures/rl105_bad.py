"""RL105 bad fixture: functional .at[] update result silently discarded."""
import jax.numpy as jnp


def zero_row(x, i):
    x.at[i].set(0.0)                  # BAD: builds a copy and throws it away
    return x
