"""replint AST rules RL101–RL105: JAX-specific hazards the type system can't see.

| code  | hazard                                                              |
|-------|---------------------------------------------------------------------|
| RL101 | buffer passed through a ``donate_argnums`` position referenced again |
| RL102 | jit param flows into Python ``if``/``while``/``range`` but is not in ``static_argnames`` |
| RL103 | Python-level branch on a traced value (``jnp.*``/``lax.*`` call in a test) inside a jitted function |
| RL104 | unseeded legacy ``np.random.*`` globals anywhere; ``time.time``/``perf_counter`` inside jitted code |
| RL105 | result of ``x.at[...].set(...)`` discarded — silently a no-op copy   |

All rules are intraprocedural and name-based: they resolve ``jax.jit``
wrappings both in decorator form (``@jax.jit``, ``@partial(jax.jit, ...)``)
and assignment form (``self._step = jax.jit(self._step_impl, ...)``), and
track donated buffers as dotted paths (``state``, ``self.cache``) through the
statement list of the enclosing function.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .engine import Finding, ModuleUnderLint

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """``Name``/``Attribute`` chain as a dotted string, else None."""
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _jit_call(node: ast.AST) -> Optional[ast.Call]:
    """Return the ``jax.jit(...)`` Call if ``node`` is one, unwrapping
    ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return None
    if _is_jax_jit(node.func):
        return node
    if _dotted(node.func) in ("partial", "functools.partial") and node.args \
            and _is_jax_jit(node.args[0]):
        return node
    return None


def _int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
            else:
                return None
        return tuple(out)
    return None


def _str_tuple(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(el.value for el in node.elts
                     if isinstance(el, ast.Constant)
                     and isinstance(el.value, str))
    return ()


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _module_functions(mod: ModuleUnderLint) -> Dict[str, ast.FunctionDef]:
    """All function defs in the module keyed by bare name (methods included;
    last definition wins, which matches attribute lookup well enough)."""
    return {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _jitted_functions(mod: ModuleUnderLint
                      ) -> List[Tuple[ast.FunctionDef, Set[str]]]:
    """Every function the module jit-wraps, with its static param names.

    Covers decorator form (``@jax.jit`` / ``@partial(jax.jit, ...)``) and
    assignment form (``f2 = jax.jit(f, static_argnames=...)`` where ``f``
    is a Name or ``self.method`` defined in this module)."""
    defs = _module_functions(mod)
    out: List[Tuple[ast.FunctionDef, Set[str]]] = []
    seen: Set[ast.FunctionDef] = set()

    def statics(jit: ast.Call, fn: ast.FunctionDef) -> Set[str]:
        names = set(_str_tuple(_kw(jit, "static_argnames") or ast.Tuple([], ast.Load())))
        nums = _int_tuple(_kw(jit, "static_argnums") or ast.Constant(None)) or ()
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        for i in nums:
            if 0 <= i < len(params):
                names.add(params[i])
        return names

    for fn in defs.values():
        for dec in fn.decorator_list:
            jit = _jit_call(dec) if isinstance(dec, ast.Call) else None
            if jit is None and _is_jax_jit(dec):
                jit = ast.Call(dec, [], [])  # bare @jax.jit, no statics
            if jit is not None and fn not in seen:
                out.append((fn, statics(jit, fn)))
                seen.add(fn)
    for node in ast.walk(mod.tree):
        jit = _jit_call(node)
        if jit is None:
            continue
        # first positional arg of jax.jit (or second of partial) is the fn
        if _dotted(jit.func) in ("partial", "functools.partial"):
            target = jit.args[1] if len(jit.args) > 1 else None
        else:
            target = jit.args[0] if jit.args else None
        # unwrap jax.jit(jax.vmap(f, ...)) down to f
        while isinstance(target, ast.Call) \
                and _dotted(target.func) in ("jax.vmap", "vmap") \
                and target.args:
            target = target.args[0]
        if target is None:
            continue
        name = _dotted(target)
        if name is None:
            continue
        bare = name.split(".")[-1]
        fn = defs.get(bare)
        if fn is not None and fn not in seen:
            out.append((fn, statics(jit, fn)))
            seen.add(fn)
    return out


def _loads_of(path: str, node: ast.AST) -> List[ast.AST]:
    """Load-context occurrences of dotted ``path`` inside ``node`` (excluding
    nested function bodies, where closure timing is out of scope)."""
    hits = []

    def visit(n: ast.AST):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return
        if isinstance(n, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(n, "ctx", None), ast.Load) \
                and _dotted(n) == path:
            hits.append(n)
            return  # don't descend: base of a matching Attribute also matches prefixes
        for c in ast.iter_child_nodes(n):
            visit(c)

    visit(node)
    return hits


def _stores_of(path: str, node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(n, "ctx", None), ast.Store) \
                and _dotted(n) == path:
            return True
    return False


# ---------------------------------------------------------------------------
# RL101 — donation-after-use
# ---------------------------------------------------------------------------


def rule_rl101_donation_after_use(mod: ModuleUnderLint) -> List[Finding]:
    """A buffer passed in a ``donate_argnums`` position is dead after the
    call; reading it again (before rebinding) is use-after-donation."""
    findings: List[Finding] = []

    # 1. collect donating callees: dotted-path -> donated positions
    donors: Dict[str, Tuple[int, ...]] = {}
    defs = _module_functions(mod)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign):
            continue
        jit = _jit_call(node.value)
        if jit is None:
            continue
        nums = _int_tuple(_kw(jit, "donate_argnums") or ast.Constant(None))
        if not nums:
            continue
        for tgt in node.targets:
            path = _dotted(tgt)
            if path:
                donors[path] = nums
    for fn in defs.values():
        for dec in fn.decorator_list:
            jit = _jit_call(dec) if isinstance(dec, ast.Call) else None
            if jit is None:
                continue
            nums = _int_tuple(_kw(jit, "donate_argnums") or ast.Constant(None))
            if nums:
                donors[fn.name] = nums

    if not donors:
        return findings

    # 2. at every call site, trace each donated arg path forward
    for call in ast.walk(mod.tree):
        if not isinstance(call, ast.Call):
            continue
        callee = _dotted(call.func)
        if callee not in donors:
            continue
        donated_paths = []
        for pos in donors[callee]:
            if pos < len(call.args):
                path = _dotted(call.args[pos])
                if path:
                    donated_paths.append(path)
        if not donated_paths:
            continue

        # locate the statement containing the call and its body list
        stmt = call
        while not isinstance(stmt, ast.stmt):
            stmt = mod.parent_of(stmt)
            if stmt is None:
                break
        if stmt is None:
            continue
        body_owner = mod.parent_of(stmt)
        body: Optional[Sequence[ast.stmt]] = None
        if body_owner is not None:
            for field in ("body", "orelse", "finalbody"):
                seq = getattr(body_owner, field, None)
                if isinstance(seq, list) and stmt in seq:
                    body = seq
                    break
        if body is None:
            continue
        idx = body.index(stmt)

        for path in donated_paths:
            if _stores_of(path, stmt):
                continue  # result rebinds the donated buffer: canonical pattern
            flagged = False
            for nxt in body[idx + 1:]:
                loads = _loads_of(path, nxt)
                if loads:
                    findings.append(Finding(
                        "RL101", mod.path, loads[0].lineno,
                        f"'{path}' was donated to '{callee}' at line "
                        f"{call.lineno} (donate_argnums) and is read again "
                        f"without being rebound"))
                    flagged = True
                    break
                if _stores_of(path, nxt):
                    break
            else:
                # body exhausted without a rebind
                if flagged:
                    continue
                if isinstance(body_owner, (ast.For, ast.While)):
                    # next loop iteration re-reads the dead buffer at the
                    # call itself
                    findings.append(Finding(
                        "RL101", mod.path, call.lineno,
                        f"'{path}' is donated to '{callee}' inside a loop "
                        f"but never rebound before the next iteration"))
                elif path.startswith("self."):
                    # an object attribute outlives the method: leaving it
                    # pointing at a donated buffer dangles for every later
                    # method call
                    findings.append(Finding(
                        "RL101", mod.path, call.lineno,
                        f"attribute '{path}' is donated to '{callee}' and "
                        f"never rebound in this method — it keeps pointing "
                        f"at the dead buffer"))
    return findings


# ---------------------------------------------------------------------------
# RL102 — jit-hygiene: non-static args in Python control flow
# ---------------------------------------------------------------------------

_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "sharding"}
_STATIC_CALLS = {"len", "isinstance", "hasattr", "type"}


def _concrete_uses(param: str, expr: ast.AST, mod: ModuleUnderLint
                   ) -> List[ast.Name]:
    """Occurrences of ``param`` in ``expr`` that would force concreteness,
    skipping trace-safe accesses (``x.shape``/``x.ndim``/``len(x)``/
    ``x is None``/``isinstance(x, ...)``)."""
    hits = []
    for n in ast.walk(expr):
        if not (isinstance(n, ast.Name) and n.id == param
                and isinstance(n.ctx, ast.Load)):
            continue
        parent = mod.parent_of(n)
        if isinstance(parent, ast.Attribute) and parent.attr in _STATIC_ATTRS:
            continue
        if isinstance(parent, ast.Call) \
                and _dotted(parent.func) in _STATIC_CALLS:
            continue
        if isinstance(parent, ast.Compare) \
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in parent.ops):
            continue  # `x is None` default-arg dispatch is trace-safe
        # inside a jnp/lax call the hazard is the branch-on-traced-value
        # itself — RL103's finding, not a static_argnames fix
        cur = parent
        traced = False
        while cur is not None and cur is not expr:
            if _is_traced_call(cur):
                traced = True
                break
            cur = mod.parent_of(cur)
        if traced:
            continue
        hits.append(n)
    return hits


def rule_rl102_jit_hygiene(mod: ModuleUnderLint) -> List[Finding]:
    """Non-static jit params steering Python ``if``/``while``/``range``
    either leak tracers or recompile per value — either way the argument
    belongs in ``static_argnames``."""
    findings: List[Finding] = []
    for fn, statics in _jitted_functions(mod):
        params = {a.arg for a in fn.args.posonlyargs + fn.args.args
                  + fn.args.kwonlyargs} - statics - {"self"}
        if not params:
            continue
        for node in ast.walk(fn):
            tests: List[ast.AST] = []
            if isinstance(node, (ast.If, ast.While)):
                tests.append(node.test)
            elif isinstance(node, ast.IfExp):
                tests.append(node.test)
            elif isinstance(node, ast.Call) and _dotted(node.func) == "range":
                tests.extend(node.args)
            for test in tests:
                for param in sorted(params):
                    for hit in _concrete_uses(param, test, mod):
                        kind = "range()" if isinstance(node, ast.Call) \
                            else "Python branch"
                        findings.append(Finding(
                            "RL102", mod.path, hit.lineno,
                            f"jit-wrapped '{fn.name}' uses arg '{param}' in "
                            f"a {kind} but '{param}' is not in "
                            f"static_argnames — recompile/tracer-leak "
                            f"hazard"))
                        break  # one finding per (test, param)
    return findings


# ---------------------------------------------------------------------------
# RL103 — Python branch on a traced value
# ---------------------------------------------------------------------------

_TRACED_PREFIXES = ("jnp.", "jax.numpy.", "lax.", "jax.lax.", "pl.", "pltpu.")


def _is_traced_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = _dotted(node.func) or ""
    return name.startswith(_TRACED_PREFIXES)


def rule_rl103_branch_on_traced(mod: ModuleUnderLint) -> List[Finding]:
    """``if jnp.any(...):`` inside a jitted function raises a
    TracerBoolConversionError at trace time (or silently freezes the branch
    under ``interpret=True`` Pallas) — use ``jnp.where``/``lax.cond``."""
    findings: List[Finding] = []
    jitted = {id(fn) for fn, _ in _jitted_functions(mod)}
    if not jitted:
        return findings
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.If, ast.While, ast.IfExp)):
            continue
        # only inside jit-wrapped functions (incl. nested defs within them)
        cur = mod.parent_of(node)
        inside = False
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(cur) in jitted:
                inside = True
                break
            cur = mod.parent_of(cur)
        if not inside:
            continue
        for sub in ast.walk(node.test):
            if _is_traced_call(sub):
                findings.append(Finding(
                    "RL103", mod.path, node.test.lineno,
                    f"Python branch on traced value "
                    f"'{ast.unparse(sub)[:60]}' inside a jitted function — "
                    f"use jnp.where/lax.cond/lax.select"))
                break
    return findings


# ---------------------------------------------------------------------------
# RL104 — hidden nondeterminism / host clocks in jitted paths
# ---------------------------------------------------------------------------

_NP_GLOBAL_RNG = {"rand", "randn", "randint", "random", "random_sample",
                  "choice", "permutation", "shuffle", "normal", "uniform",
                  "standard_normal", "binomial", "poisson", "exponential",
                  "beta", "gamma", "dirichlet"}
_HOST_CLOCKS = {"time.time", "time.perf_counter", "time.monotonic",
                "time.process_time"}


def rule_rl104_unseeded_nondeterminism(mod: ModuleUnderLint) -> List[Finding]:
    """Legacy ``np.random.*`` global-state draws are unseeded per-process
    state (use ``np.random.default_rng(seed)`` or ``jax.random``); host
    clocks inside jitted functions bake one timestamp into the trace."""
    findings: List[Finding] = []
    jitted = {id(fn) for fn, _ in _jitted_functions(mod)}

    def in_jitted(node: ast.AST) -> bool:
        cur = mod.parent_of(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(cur) in jitted:
                return True
            cur = mod.parent_of(cur)
        return False

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func) or ""
        if name.startswith(("np.random.", "numpy.random.")):
            fn_name = name.split(".")[-1]
            if fn_name in _NP_GLOBAL_RNG:
                findings.append(Finding(
                    "RL104", mod.path, node.lineno,
                    f"'{name}' draws from numpy's unseeded global RNG — "
                    f"use np.random.default_rng(seed) or jax.random"))
        elif name in _HOST_CLOCKS and in_jitted(node):
            findings.append(Finding(
                "RL104", mod.path, node.lineno,
                f"'{name}()' inside a jitted function is evaluated once at "
                f"trace time, not per call"))
    return findings


# ---------------------------------------------------------------------------
# RL105 — discarded .at[].set() result
# ---------------------------------------------------------------------------

_AT_METHODS = {"set", "add", "multiply", "divide", "min", "max", "power",
               "mul", "get", "apply"}


def rule_rl105_discarded_at_update(mod: ModuleUnderLint) -> List[Finding]:
    """``x.at[i].set(v)`` as a bare statement builds and discards a copy —
    jnp arrays are immutable, the update must be assigned."""
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Expr):
            continue
        call = node.value
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr in _AT_METHODS):
            continue
        sub = call.func.value
        if isinstance(sub, ast.Subscript) \
                and isinstance(sub.value, ast.Attribute) \
                and sub.value.attr == "at":
            findings.append(Finding(
                "RL105", mod.path, node.lineno,
                f"result of '.at[...].{call.func.attr}(...)' is discarded — "
                f"jnp arrays are immutable; assign the returned copy"))
    return findings


AST_RULES = [
    rule_rl101_donation_after_use,
    rule_rl102_jit_hygiene,
    rule_rl103_branch_on_traced,
    rule_rl104_unseeded_nondeterminism,
    rule_rl105_discarded_at_update,
]
