"""replint — JAX/Pallas-aware static analysis for the repro codebase.

Rule groups (select with ``--only``):

- ``ast``    — RL101–RL105 JAX hazard rules (:mod:`tools.lint.rules_ast`)
- ``pallas`` — RP301–RP303 kernel VMEM/grid audit (:mod:`tools.lint.pallas_audit`)
- ``docs``   — RD201/RD202 markdown links + module docstrings, RD203 obs
  metric-catalog coverage (:mod:`tools.lint.docs_rules`, absorbed from
  ``tools/docs_check.py``)

Driver: ``python tools/lint.py [paths] [--only GROUP] [--report FILE]``.
See ``tools/lint/README.md`` for the full rule catalog and suppression
syntax, and ``src/repro/lint_runtime.py`` for the runtime compile-count
sentinel that complements these static checks.
"""
from .engine import (Finding, ModuleUnderLint, Suppression, build_report,
                     emit, iter_python_files, lint_files)
from .rules_ast import AST_RULES
from .pallas_audit import (ASSUMED_DIMS, DEFAULT_VMEM_BUDGET, KernelSite,
                           audit_paths, render_readme, vmem_table)
from .docs_rules import (check_docstrings, check_links,
                         check_metric_catalog, docs_findings,
                         registered_obs_names)

GROUPS = ("ast", "pallas", "docs")

__all__ = [
    "AST_RULES", "ASSUMED_DIMS", "DEFAULT_VMEM_BUDGET", "Finding", "GROUPS",
    "KernelSite", "ModuleUnderLint", "Suppression", "audit_paths",
    "build_report", "check_docstrings", "check_links",
    "check_metric_catalog", "docs_findings", "emit", "iter_python_files",
    "lint_files", "registered_obs_names", "render_readme", "vmem_table",
]
