"""replint docs rule group RD201-RD203 (absorbed from tools/docs_check.py).

| code  | check                                                             |
|-------|-------------------------------------------------------------------|
| RD201 | broken intra-repo relative markdown link                          |
| RD202 | public ``src/repro`` module missing a module docstring            |
| RD203 | registered obs metric/event missing from the obs README catalog   |

Unlike the AST groups these are repo-wide, not per-target-path: links span
the whole markdown tree and the docstring contract covers all of
``src/repro`` regardless of what subset was passed to the driver.
``tools/docs_check.py`` remains as a thin shim over this module for one PR.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List

from .engine import REPO_ROOT, Finding

# external-material dumps, not repo docs
SKIP_MD = {"PAPER.md", "PAPERS.md", "SNIPPETS.md"}
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def repo_markdown(root: Path = REPO_ROOT) -> List[Path]:
    return [p for p in sorted(root.rglob("*.md"))
            if ".git" not in p.parts and "__pycache__" not in p.parts
            and p.name not in SKIP_MD]


def check_links(root: Path = REPO_ROOT) -> List[Finding]:
    """RD201: every relative ``[text](target)`` in repo markdown must
    resolve on disk (anchors stripped; http(s)/mailto out of scope)."""
    findings = []
    for md in repo_markdown(root):
        for ln, line in enumerate(md.read_text().splitlines(), start=1):
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                path = target.split("#")[0]
                if path and not (md.parent / path).exists():
                    findings.append(Finding(
                        "RD201", md.relative_to(root).as_posix(), ln,
                        f"broken link -> {target}"))
    return findings


def check_docstrings(root: Path = REPO_ROOT) -> List[Finding]:
    """RD202: every non-underscore module under src/repro opens with a
    module docstring (the READMEs stay navigable only if each module says
    what it is)."""
    findings = []
    for py in sorted((root / "src" / "repro").rglob("*.py")):
        if "__pycache__" in py.parts:
            continue
        if py.name.startswith("_") and py.name != "__init__.py":
            continue  # private modules opt out
        rel = py.relative_to(root).as_posix()
        try:
            tree = ast.parse(py.read_text())
        except SyntaxError as e:  # pragma: no cover - tier-1 would fail first
            findings.append(Finding("RD202", rel, e.lineno or 0,
                                    f"unparseable ({e.msg})"))
            continue
        if ast.get_docstring(tree) is None:
            findings.append(Finding("RD202", rel, 1,
                                    "missing module docstring"))
    return findings


def registered_obs_names(root: Path = REPO_ROOT) -> List[tuple]:
    """``(name, lineno)`` for every literal-string ``register(...)`` /
    ``register_event(...)`` call in the obs metric registry — extracted by
    AST, never by import, so the lint gate needs no jax (or PYTHONPATH)."""
    metrics_py = root / "src" / "repro" / "obs" / "metrics.py"
    if not metrics_py.exists():
        return []
    names = []
    for node in ast.walk(ast.parse(metrics_py.read_text())):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("register", "register_event")
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            continue
        names.append((node.args[0].value, node.lineno))
    return names


def check_metric_catalog(root: Path = REPO_ROOT) -> List[Finding]:
    """RD203: every metric/event name registered in
    ``src/repro/obs/metrics.py`` must appear (backticked or bare) in the
    ``src/repro/obs/README.md`` catalog — the README is the contract for
    what a run's JSONL can contain, so an undocumented name is doc rot."""
    names = registered_obs_names(root)
    if not names:
        return []
    readme = root / "src" / "repro" / "obs" / "README.md"
    rel = "src/repro/obs/metrics.py"
    if not readme.exists():
        return [Finding("RD203", rel, names[0][1],
                        "src/repro/obs/README.md missing but the metric "
                        "registry is non-empty")]
    text = readme.read_text()
    return [Finding("RD203", rel, ln,
                    f"registered name '{name}' not in obs README catalog")
            for name, ln in names if name not in text]


def docs_findings(root: Path = REPO_ROOT) -> List[Finding]:
    return check_links(root) + check_docstrings(root) + \
        check_metric_catalog(root)
