"""replint Pallas auditor RP301–RP303: static VMEM + grid checks on kernels.

| code  | invariant                                                          |
|-------|--------------------------------------------------------------------|
| RP301 | per-kernel VMEM footprint (in + out blocks + scratch) over budget  |
| RP302 | BlockSpec index-map arity ≠ grid rank (+ scalar-prefetch count), or index-map return rank ≠ block rank |
| RP303 | paged pool allocated without the reserved dump page (``n_pages`` where ``n_pages + 1`` is required) |

VMEM accounting: every ``pl.pallas_call`` site is parsed from the AST; each
``pl.BlockSpec`` block shape and ``pltpu.VMEM`` scratch shape is evaluated
symbolically against (a) module-level integer constants (``DEFAULT_BLOCK_D``
…), (b) a table of assumed dimension bindings for runtime sizes
(:data:`ASSUMED_DIMS` — worker count m, heads, head_dim, page size …), with
``bd``/``bw`` block names resolved to the module's own ``DEFAULT_BLOCK_D`` /
``DEFAULT_BLOCK_W`` when present. Footprint = Σ block numel × dtype bytes
(inputs assumed f32 — every kernel here upcasts to f32 in VMEM). Dims the
evaluator cannot resolve fall back to 128 and are flagged ``~`` in the table
so approximations are visible, never silent.

The same machinery renders the per-kernel VMEM table that lives between
``replint:vmem`` markers in ``src/repro/kernels/README.md`` (``--write-kernel-table``
regenerates it; ``--check-kernel-table`` fails on drift — the CI mode).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .engine import Finding, ModuleUnderLint, iter_python_files

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024  # 16 MiB per-core VMEM

# Assumed bindings for runtime dimensions (representative serving/fleet
# sizes — deliberately on the large side so the budget check is conservative).
ASSUMED_DIMS: Dict[str, int] = {
    "m": 64,        # fleet worker count (paper regime m <= 64)
    "B": 8, "S": 8,  # decode batch / serve slots
    "KV": 8, "G": 4, "H": 32, "hd": 128,
    "W": 4096,       # dense cache window
    "P": 16,         # page size (tokens per page)
    "pps": 64,       # pages per slot
    "c": 64, "h": 8, "p": 64, "n": 64,   # SSD chunk/heads/head_dim/state
    "b": 4, "nc": 4,
    "dp": 8192,      # padded aggregation dim
}
_FALLBACK_DIM = 128

DTYPE_BYTES = {
    "float32": 4, "f32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
    "float64": 8, "int64": 8,
}

MARK_BEGIN = "<!-- replint:vmem:begin -->"
MARK_END = "<!-- replint:vmem:end -->"


def _dotted(node: ast.AST) -> Optional[str]:
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _module_int_consts(tree: ast.Module) -> Dict[str, int]:
    out = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            out[node.targets[0].id] = node.value.value
    return out


@dataclasses.dataclass
class _Env:
    consts: Dict[str, int]
    assumed_used: set

    def lookup(self, name: str) -> Optional[int]:
        if name in self.consts:
            return self.consts[name]
        # block-size names resolve to the module's own default tile constants
        if name == "bd" and "DEFAULT_BLOCK_D" in self.consts:
            self.assumed_used.add(f"bd={self.consts['DEFAULT_BLOCK_D']}")
            return self.consts["DEFAULT_BLOCK_D"]
        if name in ("bw", "block_w") and "DEFAULT_BLOCK_W" in self.consts:
            self.assumed_used.add(f"{name}={self.consts['DEFAULT_BLOCK_W']}")
            return self.consts["DEFAULT_BLOCK_W"]
        if name in ASSUMED_DIMS:
            self.assumed_used.add(f"{name}={ASSUMED_DIMS[name]}")
            return ASSUMED_DIMS[name]
        return None


def _eval_dim(node: ast.AST, env: _Env) -> Tuple[int, bool]:
    """Evaluate one shape-dim expression -> (value, exact). ``exact`` is
    False once an assumed or fallback binding entered the computation."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value, True
    if isinstance(node, ast.Name):
        v = env.lookup(node.id)
        if v is not None:
            return v, node.id in env.consts
        env.assumed_used.add(f"{node.id}?={_FALLBACK_DIM}")
        return _FALLBACK_DIM, False
    if isinstance(node, ast.BinOp):
        l, le = _eval_dim(node.left, env)
        r, re_ = _eval_dim(node.right, env)
        ok = le and re_
        if isinstance(node.op, ast.Add):
            return l + r, ok
        if isinstance(node.op, ast.Sub):
            return l - r, ok
        if isinstance(node.op, ast.Mult):
            return l * r, ok
        if isinstance(node.op, ast.FloorDiv):
            return (l // r if r else _FALLBACK_DIM), ok
        if isinstance(node.op, ast.Mod):
            return (l % r if r else 0), ok
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        vals = [_eval_dim(a, env) for a in node.args]
        if vals and name in ("min", "max"):
            f = min if name == "min" else max
            return f(v for v, _ in vals), all(e for _, e in vals)
        if vals and name in ("pl.cdiv", "cdiv") and len(vals) == 2:
            (a, ae), (b, be) = vals
            return (-(-a // b) if b else _FALLBACK_DIM), ae and be
    env.assumed_used.add(f"<{type(node).__name__}>?={_FALLBACK_DIM}")
    return _FALLBACK_DIM, False


def _eval_shape(node: ast.AST, env: _Env) -> Optional[Tuple[int, ...]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(_eval_dim(el, env)[0] for el in node.elts)
    return None


def _dtype_bytes(node: Optional[ast.AST]) -> int:
    if node is None:
        return 4
    name = (_dotted(node) or "").split(".")[-1]
    return DTYPE_BYTES.get(name, 4)


def _fn_arity(fn_node: ast.AST, mod: ModuleUnderLint
              ) -> Tuple[Optional[int], Optional[int]]:
    """(n_params, return_tuple_rank) of a BlockSpec index map (Lambda or a
    Name resolving to a def in this module)."""
    if isinstance(fn_node, ast.Lambda):
        rank = len(fn_node.body.elts) if isinstance(fn_node.body, ast.Tuple) \
            else None
        return len(fn_node.args.args), rank
    if isinstance(fn_node, ast.Name):
        for n in ast.walk(mod.tree):
            if isinstance(n, ast.FunctionDef) and n.name == fn_node.id:
                rank = None
                for r in ast.walk(n):
                    if isinstance(r, ast.Return) \
                            and isinstance(r.value, ast.Tuple):
                        rank = len(r.value.elts)
                return len(n.args.args), rank
    return None, None


@dataclasses.dataclass
class BlockInfo:
    label: str                       # in[0] / out[1] / scratch[2]
    shape: Optional[Tuple[int, ...]]
    nbytes: int


@dataclasses.dataclass
class KernelSite:
    """One ``pl.pallas_call`` site with its computed VMEM budget line."""
    path: str
    line: int
    func: str                        # enclosing function name
    grid_rank: Optional[int]
    grid_src: str
    blocks: List[BlockInfo]
    assumed: List[str]
    prefetch: int = 0

    @property
    def vmem_bytes(self) -> int:
        return sum(b.nbytes for b in self.blocks)


def _enclosing_func_name(mod: ModuleUnderLint, node: ast.AST) -> str:
    fn = mod.enclosing_function(node)
    return fn.name if fn is not None else "<module>"


def audit_module(mod: ModuleUnderLint,
                 budget: int = DEFAULT_VMEM_BUDGET
                 ) -> Tuple[List[KernelSite], List[Finding]]:
    """All pallas_call sites in one module, plus RP30x findings."""
    sites: List[KernelSite] = []
    findings: List[Finding] = []
    consts = _module_int_consts(mod.tree)

    for call in ast.walk(mod.tree):
        if not isinstance(call, ast.Call):
            continue
        if (_dotted(call.func) or "").split(".")[-1] != "pallas_call":
            continue
        env = _Env(dict(consts), set())
        prefetch = 0
        grid_node = _kw(call, "grid")
        in_specs = _kw(call, "in_specs")
        out_specs = _kw(call, "out_specs")
        scratch = _kw(call, "scratch_shapes")
        gs = _kw(call, "grid_spec")
        if gs is not None and isinstance(gs, ast.Name):
            # grid_spec bound to a local: chase the assignment in this function
            gs_name = gs.id
            owner = mod.enclosing_function(call)
            for n in ast.walk(owner if owner is not None else mod.tree):
                if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                        and isinstance(n.targets[0], ast.Name) \
                        and n.targets[0].id == gs_name:
                    gs = n.value
                    break
        if isinstance(gs, ast.Call):
            grid_node = _kw(gs, "grid") or grid_node
            in_specs = _kw(gs, "in_specs") or in_specs
            out_specs = _kw(gs, "out_specs") or out_specs
            scratch = _kw(gs, "scratch_shapes") or scratch
            pf = _kw(gs, "num_scalar_prefetch")
            if isinstance(pf, ast.Constant) and isinstance(pf.value, int):
                prefetch = pf.value

        grid_rank = len(grid_node.elts) \
            if isinstance(grid_node, (ast.Tuple, ast.List)) else None
        grid_src = ast.unparse(grid_node) if grid_node is not None else "?"

        out_shape = _kw(call, "out_shape")
        out_dtypes: List[Optional[ast.AST]] = []
        shapes = out_shape.elts if isinstance(out_shape, (ast.Tuple, ast.List)) \
            else ([out_shape] if out_shape is not None else [])
        for s in shapes:
            out_dtypes.append(s.args[1] if isinstance(s, ast.Call)
                              and len(s.args) > 1 else None)

        blocks: List[BlockInfo] = []

        def handle_spec(spec: ast.AST, label: str, dtype_node=None):
            if not isinstance(spec, ast.Call):
                return
            shape_node = spec.args[0] if spec.args else None
            fn_node = spec.args[1] if len(spec.args) > 1 else None
            shape = _eval_shape(shape_node, env) if shape_node is not None \
                else None
            nbytes = 0
            if shape:
                numel = 1
                for d in shape:
                    numel *= max(d, 1)
                nbytes = numel * _dtype_bytes(dtype_node)
            blocks.append(BlockInfo(label, shape, nbytes))
            if fn_node is not None and grid_rank is not None:
                nargs, ret_rank = _fn_arity(fn_node, mod)
                expected = grid_rank + prefetch
                if nargs is not None and nargs != expected:
                    findings.append(Finding(
                        "RP302", mod.path, spec.lineno,
                        f"index map of {label} takes {nargs} args but grid "
                        f"rank {grid_rank} + {prefetch} scalar-prefetch "
                        f"refs = {expected}"))
                block_rank = len(shape_node.elts) \
                    if isinstance(shape_node, (ast.Tuple, ast.List)) else None
                if ret_rank is not None and block_rank is not None \
                        and ret_rank != block_rank:
                    findings.append(Finding(
                        "RP302", mod.path, spec.lineno,
                        f"index map of {label} returns {ret_rank} indices "
                        f"for a rank-{block_rank} block"))

        if isinstance(in_specs, (ast.Tuple, ast.List)):
            for i, spec in enumerate(in_specs.elts):
                handle_spec(spec, f"in[{i}]")
        outs = out_specs.elts if isinstance(out_specs, (ast.Tuple, ast.List)) \
            else ([out_specs] if out_specs is not None else [])
        for i, spec in enumerate(outs):
            handle_spec(spec, f"out[{i}]",
                        out_dtypes[i] if i < len(out_dtypes) else None)
        if isinstance(scratch, (ast.Tuple, ast.List)):
            for i, sc in enumerate(scratch.elts):
                if not isinstance(sc, ast.Call):
                    continue
                kind = (_dotted(sc.func) or "").split(".")[-1]
                if kind != "VMEM":   # SMEM scalars are not VMEM-resident
                    continue
                shape = _eval_shape(sc.args[0], env) if sc.args else None
                dtype_node = sc.args[1] if len(sc.args) > 1 else None
                nbytes = 0
                if shape:
                    numel = 1
                    for d in shape:
                        numel *= max(d, 1)
                    nbytes = numel * _dtype_bytes(dtype_node)
                blocks.append(BlockInfo(f"scratch[{i}]", shape, nbytes))

        site = KernelSite(mod.path, call.lineno,
                          _enclosing_func_name(mod, call),
                          grid_rank, grid_src, blocks,
                          sorted(env.assumed_used), prefetch)
        sites.append(site)
        if site.vmem_bytes > budget:
            findings.append(Finding(
                "RP301", mod.path, call.lineno,
                f"kernel '{site.func}' VMEM footprint "
                f"{site.vmem_bytes / 2**20:.2f} MiB exceeds budget "
                f"{budget / 2**20:.0f} MiB"))

    findings.extend(_check_dump_page(mod))
    return sites, findings


def _check_dump_page(mod: ModuleUnderLint) -> List[Finding]:
    """RP303: in modules using the block-table idiom (``np.full(...,
    n_pages)`` as the unallocated sentinel), every page-pool allocation whose
    leading dim involves ``n_pages`` must reserve the dump page
    (``n_pages + 1``)."""
    has_table_sentinel = False
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) \
                and (_dotted(node.func) or "").endswith("full"):
            for arg in node.args[1:] + [k.value for k in node.keywords]:
                d = _dotted(arg)
                if d is not None and d.split(".")[-1] == "n_pages":
                    has_table_sentinel = True
    if not has_table_sentinel:
        return []

    findings = []
    _ALLOC = {"zeros", "empty", "ones", "full"}
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and (_dotted(node.func) or "").split(".")[-1] in _ALLOC
                and node.args):
            continue
        shape = node.args[0]
        if not isinstance(shape, (ast.Tuple, ast.List)) or not shape.elts:
            continue
        lead = shape.elts[0]
        uses_n_pages = any(isinstance(n, ast.Name) and n.id == "n_pages"
                           for n in ast.walk(lead))
        if not uses_n_pages:
            continue
        reserved = isinstance(lead, ast.BinOp) \
            and isinstance(lead.op, ast.Add) \
            and ((isinstance(lead.right, ast.Constant) and lead.right.value == 1)
                 or (isinstance(lead.left, ast.Constant) and lead.left.value == 1))
        if not reserved:
            findings.append(Finding(
                "RP303", mod.path, node.lineno,
                "page pool sized by 'n_pages' without the reserved dump page "
                "— allocate 'n_pages + 1' (block tables point unallocated "
                "logical pages at the last physical page)"))
    return findings


def audit_paths(paths: List[Path], budget: int = DEFAULT_VMEM_BUDGET
                ) -> Tuple[List[KernelSite], List[Finding]]:
    """Audit every file under ``paths`` that mentions ``pallas_call`` or the
    page-table idiom (so serve/cache.py gets the RP303 check too)."""
    sites: List[KernelSite] = []
    findings: List[Finding] = []
    for path in iter_python_files(paths):
        text = path.read_text()
        if "pallas_call" not in text and "n_pages" not in text:
            continue
        mod = ModuleUnderLint(path)
        s, f = audit_module(mod, budget)
        sites.extend(s)
        findings.extend(f)
    return sites, findings


# ---------------------------------------------------------------------------
# kernels/README.md VMEM table
# ---------------------------------------------------------------------------


def vmem_table(sites: List[KernelSite],
               budget: int = DEFAULT_VMEM_BUDGET) -> str:
    """Markdown table of per-kernel VMEM footprints (the generated block in
    kernels/README.md). ``~`` marks footprints that used assumed dims."""
    lines = [
        "| kernel | site | grid | VMEM (KiB) | budget | assumed dims |",
        "|---|---|---|---:|---|---|",
    ]
    for s in sorted(sites, key=lambda s: (s.path, s.line)):
        kib = s.vmem_bytes / 1024
        approx = "~" if s.assumed else ""
        status = "over budget" if s.vmem_bytes > budget else "ok"
        assumed = ", ".join(s.assumed) if s.assumed else "—"
        fname = s.path.rsplit("/", 1)[-1]
        lines.append(
            f"| `{s.func}` | `{fname}:{s.line}` | `{s.grid_src}` "
            f"| {approx}{kib:.1f} | {status} | {assumed} |")
    lines.append("")
    lines.append(f"Budget: {budget / 2**20:.0f} MiB/core. Generated by "
                 f"`python tools/lint.py --write-kernel-table`; CI checks "
                 f"drift with `--check-kernel-table`. Assumed runtime dims "
                 f"come from `tools/lint/pallas_audit.py:ASSUMED_DIMS`.")
    return "\n".join(lines)


def render_readme(readme_text: str, table: str) -> str:
    block = f"{MARK_BEGIN}\n{table}\n{MARK_END}"
    if MARK_BEGIN in readme_text and MARK_END in readme_text:
        head, rest = readme_text.split(MARK_BEGIN, 1)
        _, tail = rest.split(MARK_END, 1)
        return head + block + tail
    sep = "" if readme_text.endswith("\n\n") else \
        ("\n" if readme_text.endswith("\n") else "\n\n")
    return (readme_text + sep + "## Static VMEM audit (generated)\n\n"
            + block + "\n")
