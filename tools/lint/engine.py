"""replint core: findings, suppressions, file walking, report assembly.

A :class:`Finding` is one (rule, file, line) diagnostic. Rules are callables
``rule(module: ModuleUnderLint) -> list[Finding]`` registered per group in
``tools/lint/__init__.py``; the driver runs the requested groups over every
Python file in the target paths, applies ``# replint: disable=RLxxx``
suppressions (which REQUIRE a ``-- justification`` tail and are themselves
counted in the report), and exits non-zero on any unsuppressed finding.

Suppression grammar, one source line::

    risky_call()   # replint: disable=RL101 -- insert donates; rebound below

Multiple codes separate with commas (``disable=RL101,RL104``). A suppression
with no justification is a finding in its own right (``RL000``), so silent
opt-outs cannot accumulate.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional

REPO_ROOT = Path(__file__).resolve().parents[2]

# suppression with justification: "# replint: disable=RL101[,RL104] -- why"
_SUPPRESS_RE = re.compile(
    r"#\s*replint:\s*disable=([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)"
    r"(?:\s*--\s*(\S.*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: stable rule code, location, human message."""
    code: str
    path: str               # repo-relative posix path
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    """A parsed ``# replint: disable=`` pragma (always reported, never silent)."""
    codes: tuple
    path: str
    line: int
    justification: str


class ModuleUnderLint:
    """One parsed source file handed to every AST rule.

    Caches the parse tree, the raw source lines (for suppression scanning)
    and a parent-pointer map (``parent_of``) so rules can walk outward from a
    node — e.g. to find the enclosing function of a call site."""

    def __init__(self, path: Path, root: Path = REPO_ROOT):
        self.abspath = path
        self.path = path.resolve().relative_to(root).as_posix() \
            if path.resolve().is_relative_to(root) else path.as_posix()
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parents[child] = node

    def parent_of(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parent_of(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parent_of(cur)
        return None

    def suppressions(self) -> List[Suppression]:
        out = []
        for ln, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if m:
                codes = tuple(c.strip() for c in m.group(1).split(","))
                out.append(Suppression(codes, self.path, ln,
                                       (m.group(2) or "").strip()))
        return out


Rule = Callable[[ModuleUnderLint], List[Finding]]


def iter_python_files(paths: Iterable[Path]) -> List[Path]:
    """Every ``*.py`` under the given files/dirs, skipping caches and the
    lint fixtures (they are intentionally-bad snippets)."""
    files: List[Path] = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(q for q in sorted(p.rglob("*.py"))
                         if "__pycache__" not in q.parts
                         and "fixtures" not in q.parts)
    return files


def apply_suppressions(findings: List[Finding],
                       sups: List[Suppression]) -> tuple[List[Finding],
                                                         List[Finding]]:
    """Split findings into (active, suppressed). A suppression covers its own
    source line only; unjustified pragmas surface as RL000 findings."""
    covered = {}
    for s in sups:
        for c in s.codes:
            covered.setdefault((s.path, s.line, c), s)
    active, suppressed = [], []
    for f in findings:
        if (f.path, f.line, f.code) in covered:
            suppressed.append(f)
        else:
            active.append(f)
    for s in sups:
        if not s.justification:
            active.append(Finding(
                "RL000", s.path, s.line,
                f"suppression of {','.join(s.codes)} has no justification "
                f"(write '# replint: disable=<codes> -- <why>')"))
    return active, suppressed


def lint_files(files: List[Path], rules: List[Rule]
               ) -> tuple[List[Finding], List[Finding], List[Suppression]]:
    """Run ``rules`` over ``files``; returns (active, suppressed, pragmas)."""
    findings: List[Finding] = []
    sups: List[Suppression] = []
    for path in files:
        try:
            mod = ModuleUnderLint(path)
        except SyntaxError as e:
            findings.append(Finding("RL999", str(path), e.lineno or 0,
                                    f"unparseable: {e.msg}"))
            continue
        sups.extend(mod.suppressions())
        for rule in rules:
            findings.extend(rule(mod))
    active, suppressed = apply_suppressions(findings, sups)
    active.sort(key=lambda f: (f.path, f.line, f.code))
    suppressed.sort(key=lambda f: (f.path, f.line, f.code))
    return active, suppressed, sups


def build_report(active: List[Finding], suppressed: List[Finding],
                 sups: List[Suppression], *, groups: List[str],
                 files: List[Path], extra: Optional[dict] = None) -> dict:
    """JSON-ready lint report (the CI artifact)."""
    report = {
        "tool": "replint",
        "groups": groups,
        "n_files": len(files),
        "n_findings": len(active),
        "n_suppressed": len(suppressed),
        "findings": [f.as_dict() for f in active],
        "suppressed": [f.as_dict() for f in suppressed],
        "suppressions": [dataclasses.asdict(s) for s in sups],
    }
    if extra:
        report.update(extra)
    return report


def emit(report: dict, report_path: Optional[str], stream=sys.stderr) -> int:
    """Print findings, optionally write the JSON report; return exit code."""
    for f in report["findings"]:
        print(f"{f['path']}:{f['line']}: {f['code']} {f['message']}",
              file=stream)
    if report_path:
        Path(report_path).write_text(json.dumps(report, indent=2) + "\n")
    n = report["n_findings"]
    tag = "replint"
    if n:
        print(f"{tag}: {n} finding(s) "
              f"({report['n_suppressed']} suppressed)", file=stream)
        return 1
    print(f"{tag}: OK ({report['n_files']} files, "
          f"{report['n_suppressed']} suppressed finding(s))")
    return 0
