"""Docs health check, run by CI next to the serve smoke step.

Two failure classes, both cheap and deterministic:

1. **Broken intra-repo markdown links** — every ``[text](target)`` in the
   repo's own ``*.md`` files whose target is a relative path must resolve on
   disk (anchors are stripped; http(s)/mailto links are out of scope).
   PAPER.md / PAPERS.md / SNIPPETS.md are retrieval dumps of external
   material, not repo docs, and are skipped.
2. **Public modules missing docstrings** — every non-underscore module under
   ``src/repro`` must open with a module docstring; the READMEs can only
   stay navigable if each module says what it is.

    python tools/docs_check.py          # exit 1 + report on any failure

Importable as a module (``check_links`` / ``check_docstrings``) so the tier-1
suite can pin the repo green without a subprocess.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
# external-material dumps, not repo docs
SKIP_MD = {"PAPER.md", "PAPERS.md", "SNIPPETS.md"}
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _repo_markdown() -> list[Path]:
    return [p for p in sorted(ROOT.rglob("*.md"))
            if ".git" not in p.parts and "__pycache__" not in p.parts
            and p.name not in SKIP_MD]


def check_links() -> list[str]:
    """Broken relative links in repo markdown; [] when healthy."""
    errors = []
    for md in _repo_markdown():
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if path and not (md.parent / path).exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_docstrings() -> list[str]:
    """Public src/repro modules missing a module docstring; [] when healthy."""
    errors = []
    for py in sorted((ROOT / "src" / "repro").rglob("*.py")):
        if "__pycache__" in py.parts:
            continue
        if py.name.startswith("_") and py.name != "__init__.py":
            continue  # private modules opt out
        try:
            tree = ast.parse(py.read_text())
        except SyntaxError as e:  # pragma: no cover - would fail tests anyway
            errors.append(f"{py.relative_to(ROOT)}: unparseable ({e})")
            continue
        if ast.get_docstring(tree) is None:
            errors.append(f"{py.relative_to(ROOT)}: missing module docstring")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    if errors:
        print(f"docs-check: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    n_md = len(_repo_markdown())
    print(f"docs-check: OK ({n_md} markdown files, links + docstrings clean)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
