"""Back-compat shim over the replint ``docs`` rule group (one PR only).

The docs health check moved into the lint driver as rules RD201/RD202 —
``python tools/lint.py --only docs`` is the canonical invocation now (see
tools/lint/README.md). This entry point keeps the old CLI and the old
``check_links()``/``check_docstrings() -> list[str]`` API alive for one PR
so external callers can migrate.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from lint import docs_rules as _docs


def check_links() -> list[str]:
    """Broken relative links in repo markdown; [] when healthy."""
    return [f"{f.path}: broken link -> {f.message.split('-> ')[-1]}"
            for f in _docs.check_links()]


def check_docstrings() -> list[str]:
    """Public src/repro modules missing a module docstring; [] when healthy."""
    return [f"{f.path}: {f.message}" for f in _docs.check_docstrings()]


def main() -> int:
    findings = _docs.docs_findings()
    for f in findings:
        print(f"docs-check: {f.render()}", file=sys.stderr)
    if findings:
        print(f"docs-check: {len(findings)} problem(s)", file=sys.stderr)
        return 1
    n_md = len(_docs.repo_markdown())
    print(f"docs-check: OK ({n_md} markdown files, links + docstrings clean)"
          f" [shim — use: python tools/lint.py --only docs]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
