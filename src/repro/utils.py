"""Shared small utilities: pytree flattening, PRNG helpers, logging."""
from __future__ import annotations

import logging
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[%(asctime)s %(name)s] %(message)s", "%H:%M:%S"))
    logger.addHandler(_h)
    logger.setLevel(logging.INFO)


def ravel_pytree_fn(tree: Pytree) -> tuple[jnp.ndarray, Callable[[jnp.ndarray], Pytree]]:
    """Like jax.flatten_util.ravel_pytree but returns (flat, unravel)."""
    from jax.flatten_util import ravel_pytree

    flat, unravel = ravel_pytree(tree)
    return flat, unravel


def tree_size(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha*x + y."""
    return jax.tree_util.tree_map(lambda u, v: alpha * u + v, x, y)


def tree_sqnorm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(tree_sqnorm(tree))


def split_key(key, n: int):
    return jax.random.split(key, n)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def timeit_median(fn: Callable[[], Any], iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds of fn(); blocks on jax arrays."""
    for _ in range(warmup):
        out = fn()
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
