"""Shared small utilities: pytree flattening, PRNG helpers, logging, and the
post-SPMD HLO collective-bytes parser (import-side-effect free — unlike
``repro.launch.dryrun``, which forces a placeholder device platform via
XLA_FLAGS at import time and must never be imported just for the parser)."""
from __future__ import annotations

import logging
import re
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[%(asctime)s %(name)s] %(message)s", "%H:%M:%S"))
    logger.addHandler(_h)
    logger.setLevel(logging.INFO)


def ravel_pytree_fn(tree: Pytree) -> tuple[jnp.ndarray, Callable[[jnp.ndarray], Pytree]]:
    """Like jax.flatten_util.ravel_pytree but returns (flat, unravel)."""
    from jax.flatten_util import ravel_pytree

    flat, unravel = ravel_pytree(tree)
    return flat, unravel


def tree_size(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha*x + y."""
    return jax.tree_util.tree_map(lambda u, v: alpha * u + v, x, y)


def tree_sqnorm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(tree_sqnorm(tree))


def split_key(key, n: int):
    return jax.random.split(key, n)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def timeit_median(fn: Callable[[], Any], iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds of fn(); blocks on jax arrays."""
    for _ in range(warmup):
        out = fn()
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


# ---------------------------------------------------------------------------
# Post-SPMD HLO collective accounting (used by launch/dryrun.py, the agghier
# bench, and the hierarchy HLO tests)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)"
    r"\[([0-9,]*)\]")

# ``(-start)?(?![\w-])`` keeps async HLO pairs from double-counting: the
# ``-start`` op matches once (only its RESULT tuple element is counted — the
# tuple also repeats the operand shape), the ``-done`` op is rejected —
# otherwise "all-reduce-done" would count as a second all-reduce (and
# "all-gather-done" as a spurious all-gather).
_COLL_RE = re.compile(
    r"(?:ROOT )?%?[\w.\-]+ = (.*?) (all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?(?![\w.\-])")


def _one_shape_bytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _shape_bytes(text: str) -> int:
    return sum(_one_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(text))


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind bytes (per device), parsed from post-SPMD HLO.

    Bytes are the result-shape sizes (all-reduce counted twice for the
    ring's reduce-scatter + all-gather phases)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line.strip())
        if not m:
            continue
        result_txt, kind, start = m.groups()
        if start:
            # async: the -start tuple is (operands..., results...) — count
            # only the results half (variadic combined collectives carry N
            # of each; the whole tuple would report 2x the bytes of the same
            # collective lowered synchronously). Dimensionless u32[] context
            # scalars some -start tuples append are dropped first.
            shapes = [sh for sh in _SHAPE_RE.findall(result_txt) if sh[1]]
            b = sum(_one_shape_bytes(*sh) for sh in shapes[len(shapes) // 2:])
        else:
            b = _shape_bytes(result_txt)
        if kind == "all-reduce":
            b *= 2
        out[kind] += b
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out
