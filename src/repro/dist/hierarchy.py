"""Hierarchical cross-pod robust aggregation (Remark 4.1 at multi-pod scale).

``dist.robust`` already reduces the CTMA/GM/Krum distance passes to a single
global ``(m,)`` vector, but the stacked momenta must be co-resident on one
pod's devices — on the 2×16×16 production mesh that means gathering every
group's full momentum buffer over the ``pod`` axis before aggregating. This
module removes that gather: the stacked ``(G, ...)`` momenta live PARAMETER-
SHARDED over the ``pod`` (and, when divisible, ``model``) mesh axes, each
device computes the distance contribution of its local parameter slice, and a
``lax.psum`` over the reduce axes turns the per-device partial squared-norm
sums into the same global ``(m,)`` (or ``(m, m)`` for Krum) vector the
single-host path produces. The momentum leaves themselves never cross a pod
boundary — only m-sized scalars do, which is what the paper's O(dm)
bandwidth model assumes of the aggregation step.

Why this decomposition is exact:

- ‖x_i − y‖² = Σ_shards ‖x_i − y‖²_shard — squared distances are additive
  over any partition of the coordinates, so a psum of per-shard partials IS
  the global distance (same identity ``stacked_sqdist`` uses across leaves).
- the anchors (ω-CWMed / ω-CWTM / weighted mean) and the final reweighted
  combines are coordinate-wise, hence computed shard-locally with the global
  ``(m,)`` coefficients — no communication at all.
- the trim/reweight coefficients (``trim_weights``, Weiszfeld 1/dist) are
  pure functions of the global distance vector and the replicated weights, so
  every device derives identical coefficients deterministically.

Layout: ``momentum_pspec`` places ``pod`` on the trailing-most leaf dim it
divides, then ``model`` on another divisible dim; the leading group axis is
never sharded (the coordinate-wise anchors need all m rows of each local
coordinate slice). Leaves with no divisible dim stay replicated — their
partial sums are scaled by ``covered/total`` so the psum counts them once.

Entry points mirror ``dist.robust`` (``hier_ctma``, ``hier_gm``, ...) and
self-dispatch on :func:`repro.dist.context.current_mesh`: outside a mesh
context, or on a mesh without a >1 ``pod`` axis, they fall back to the
single-host stacked path bit-for-bit. The ``repro.agg`` registry routes
stacked-pytree inputs through these wrappers for ``@hier`` and ``@auto``
backends, so ``make_robust_train_step`` lowered under a multi-pod
``mesh_context`` picks the hierarchical path with no call-site changes.

NOTE: mesh detection happens at trace time — a step jitted under one mesh
context caches that mesh's shard_map; build a fresh jit per mesh (the dry-run
and launchers already do).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.4.35 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # this container's 0.4.37 ships it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.aggregators import weighted_cwmed, weighted_cwtm
from repro.dist.context import current_axis_size, current_mesh
from repro.dist import robust as _stk

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map

POD_AXIS = "pod"
# Axes the distance psum reduces over. ``pod`` is the cross-pod requirement;
# ``model`` rides along when it divides a second dim so the stacked buffers
# are not replicated across the in-pod tensor-parallel ranks.
REDUCE_AXES = (POD_AXIS, "model")


def _axis_size(mesh, name: str) -> int:
    try:
        return int(mesh.shape.get(name, 1))
    except AttributeError:  # pragma: no cover - mesh-like without .shape dict
        return 1


def pod_count(mesh) -> int:
    """Size of the ``pod`` axis (1 when absent / no mesh)."""
    return _axis_size(mesh, POD_AXIS) if mesh is not None else 1


def reduce_axes(mesh) -> tuple:
    """The mesh axes the hierarchical distance psum runs over."""
    return tuple(a for a in REDUCE_AXES
                 if a in mesh.axis_names and _axis_size(mesh, a) > 1)


def momentum_pspec(shape: tuple, mesh) -> P:
    """Pod-sharded layout of one stacked ``(G, ...)`` momentum leaf.

    ``pod`` goes on the trailing-most dim it divides, ``model`` on another
    divisible dim; the leading group axis stays unsharded so the coordinate-
    wise anchors see all m rows of every local coordinate."""
    spec: list = [None] * len(shape)
    for axis in reduce_axes(mesh):
        n = _axis_size(mesh, axis)
        for i in range(len(shape) - 1, 0, -1):
            if spec[i] is None and shape[i] % n == 0 and shape[i] >= n:
                spec[i] = axis
                break
    return P(*spec)


def _hier_specs(tree: Pytree, mesh):
    """(in_specs, out_specs, fracs) for the shard_map call.

    ``fracs[leaf] = covered / total`` where covered is the product of reduce-
    axis sizes actually sharding the leaf: replicated leaves contribute the
    same partial on every reduce-axis coordinate, so scaling by covered/total
    makes the psum count them exactly once."""
    axes = reduce_axes(mesh)
    total = 1
    for a in axes:
        total *= _axis_size(mesh, a)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    specs = [momentum_pspec(tuple(l.shape), mesh) for l in leaves]
    fracs = []
    for sp in specs:
        covered = 1
        for a in axes:
            if a in sp:
                covered *= _axis_size(mesh, a)
        fracs.append(covered / total)
    unf = partial(jax.tree_util.tree_unflatten, treedef)
    return (unf(specs), unf([P(*sp[1:]) for sp in specs]), unf(fracs), axes)


# ---------------------------------------------------------------------------
# Shard-local kernels (run inside shard_map; ``tree`` leaves are local blocks)
# ---------------------------------------------------------------------------

# Leaf reshaping and the coefficient combine are the SAME computation as the
# single-host stacked path, applied to local blocks — share the code so the
# bit-for-bit fallback equivalence can never drift.
_flat2 = _stk._flat2
_local_combine = _stk._combine


def _global_sqdist(tree: Pytree, y: Pytree, fracs: Pytree, axes) -> Array:
    """THE hierarchical distance pass: this device's frac-scaled partial of
    the shared stacked distance kernel + one (m,)-sized psum over the reduce
    axes — the only cross-pod communication in this module."""
    return lax.psum(_stk.stacked_sqdist(tree, y, fracs), axes)


def _body_mean(tree, s, fracs, axes):
    return _local_combine(tree, s, jnp.sum(s))


def _body_cwmed(tree, s, fracs, axes):
    return _tmap(lambda x: weighted_cwmed(_flat2(x).astype(jnp.float32), s)
                 .reshape(x.shape[1:]), tree)


def _body_cwtm(tree, s, fracs, axes, *, lam: float):
    return _tmap(lambda x: weighted_cwtm(_flat2(x).astype(jnp.float32), s,
                                         lam=lam).reshape(x.shape[1:]), tree)


def _body_gm(tree, s, fracs, axes, *, iters: int = 32, eps: float = 1e-8):
    y0 = _body_cwmed(tree, s, fracs, axes)

    def body(_, y):
        dist = jnp.sqrt(jnp.maximum(_global_sqdist(tree, y, fracs, axes), 0.0))
        invd = s / jnp.maximum(dist, eps)
        return _local_combine(tree, invd, jnp.sum(invd))

    return lax.fori_loop(0, iters, body, y0)


def _body_ctma(tree, s, fracs, axes, *, lam: float, base_body: Callable):
    from repro.kernels.wctma_fused import trim_weights  # pure jnp, no Pallas

    x0 = base_body(tree, s, fracs, axes)
    # the global distances (and hence the trim coefficients) are identical on
    # every device, so the trimmed combine stays shard-local
    kept, thresh = trim_weights(_global_sqdist(tree, x0, fracs, axes), s, lam)
    return _local_combine(tree, kept, jnp.maximum(thresh, 1e-30))


def _body_krum(tree, s, fracs, axes, *, n_byz: int = 1):
    # shared pairwise kernel + scoring with the stacked path; the psum moves
    # (m, m) scalars, never the buffers
    d2 = lax.psum(_stk.stacked_pairwise_sqdist(tree, fracs), axes)
    i = _stk.krum_select(d2, n_byz)
    return _tmap(lambda x: x[i], tree)


# CTMA anchor bodies resolvable by name, with their stacked fallbacks.
_BASE_BODIES = {
    "cwmed": (_body_cwmed, _stk.stacked_cwmed),
    "mean": (_body_mean, _stk.stacked_mean),
    "gm": (_body_gm, _stk.stacked_gm),
    "cwtm": (_body_cwtm, _stk.stacked_cwtm),
}


# ---------------------------------------------------------------------------
# Mesh dispatch
# ---------------------------------------------------------------------------

def _run_hier(body: Callable, tree: Pytree, s: Optional[Array], mesh) -> Pytree:
    m = jax.tree_util.tree_leaves(tree)[0].shape[0]
    w = jnp.ones((m,), jnp.float32) if s is None else s.astype(jnp.float32)
    in_specs, out_specs, fracs, axes = _hier_specs(tree, mesh)
    fn = _shard_map(lambda t, sw: body(t, sw, fracs, axes), mesh=mesh,
                    in_specs=(in_specs, P()), out_specs=out_specs,
                    check_rep=False)
    return fn(tree, w)


def _dispatch(body: Callable, fallback: Callable, tree: Pytree,
              s: Optional[Array]) -> Pytree:
    if current_axis_size(POD_AXIS) <= 1:
        return fallback(tree, s)
    return _run_hier(body, tree, s, current_mesh())


def hier_mean(tree: Pytree, s: Optional[Array] = None) -> Pytree:
    return _dispatch(_body_mean, _stk.stacked_mean, tree, s)


def hier_cwmed(tree: Pytree, s: Optional[Array] = None) -> Pytree:
    return _dispatch(_body_cwmed, _stk.stacked_cwmed, tree, s)


def hier_cwtm(tree: Pytree, s: Optional[Array] = None, *,
              lam: float = 0.25) -> Pytree:
    return _dispatch(partial(_body_cwtm, lam=lam),
                     partial(_stk.stacked_cwtm, lam=lam), tree, s)


def hier_gm(tree: Pytree, s: Optional[Array] = None, *, iters: int = 32,
            eps: float = 1e-8) -> Pytree:
    return _dispatch(partial(_body_gm, iters=iters, eps=eps),
                     partial(_stk.stacked_gm, iters=iters, eps=eps), tree, s)


def hier_krum(tree: Pytree, s: Optional[Array] = None, *,
              n_byz: int = 1) -> Pytree:
    return _dispatch(partial(_body_krum, n_byz=n_byz),
                     partial(_stk.stacked_krum, n_byz=n_byz), tree, s)


def hier_ctma(tree: Pytree, s: Optional[Array] = None, *, lam: float,
              base: str = "cwmed",
              base_kw: Optional[dict] = None) -> Pytree:
    """ω-CTMA with the anchor resolved by NAME (the registry composes specs
    as strings and routes the anchor's own parameters — gm's iters/eps,
    cwtm's lam — through ``base_kw``); the stacked twin gets the matching
    callable fallback with identical parameters."""
    if base not in _BASE_BODIES:
        raise KeyError(f"hier ctma base {base!r}; choose from "
                       f"{sorted(_BASE_BODIES)}")
    base_body, base_stacked = _BASE_BODIES[base]
    kw = base_kw or {}
    return _dispatch(
        partial(_body_ctma, lam=lam, base_body=partial(base_body, **kw)),
        partial(_stk.stacked_ctma, lam=lam,
                base=partial(base_stacked, **kw) if kw else base_stacked),
        tree, s)
