"""Sharding policies for the production meshes (launch/mesh.py).

All functions map SHAPE pytrees (ShapeDtypeStructs or arrays) to NamedSharding
pytrees — they never touch data, so the dry-run can build full sharded
signatures without allocating a parameter.

Policy:
- batches    : leading (batch) dim over the data-parallel axes.
- params     : the trailing-most dim divisible by the ``model`` axis is
               tensor-parallel; with ``cfg.fsdp`` one remaining dim is
               additionally sharded over pod×data (ZeRO-3 style). Scanned
               layer groups (under the ``groups`` key) carry a leading stack
               dim which is never chosen.
- KV caches  : batch dim over data-parallel axes (weight-stationary decode
               keeps params resident and moves activations).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes
from repro.models.config import ModelConfig

Pytree = Any


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh, axes) -> bool:
    """Whether ``dim`` divides evenly over the combined mesh axes."""
    n = _axes_size(mesh, tuple(a for a in axes if a in mesh.axis_names))
    return n > 1 and dim % n == 0


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _in_groups(path) -> bool:
    """True for leaves under a ``groups`` key (lax.scan layer/cache stacks,
    whose axis 0 is the n_full stack dim, not a shardable tensor dim)."""
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key == "groups":
            return True
    return False


def _leaf_param_spec(shape: tuple, mesh, *, fsdp: bool, start: int) -> P:
    """Pick the tensor-parallel (and optionally fsdp) dims for one leaf."""
    spec: list = [None] * len(shape)
    model_n = mesh.shape.get("model", 1)
    for i in range(len(shape) - 1, start - 1, -1):
        if model_n > 1 and shape[i] % model_n == 0 and shape[i] >= model_n:
            spec[i] = "model"
            break
    if fsdp:
        dp = dp_axes(mesh)
        dp_n = _axes_size(mesh, dp)
        for i in range(len(shape) - 1, start - 1, -1):
            if spec[i] is None and dp_n > 1 and shape[i] % dp_n == 0 and shape[i] >= dp_n:
                spec[i] = dp
                break
    return P(*spec)


def param_sharding(cfg: ModelConfig, mesh, tree: Pytree, mode: str = "train"
                   ) -> Pytree:
    """Per-leaf NamedShardings for a parameter(-like) pytree.

    ``mode='decode'`` uses the same weight-stationary layout — params stay
    resident, sharded over ``model`` along contraction/output dims.
    """
    fsdp = bool(cfg.fsdp) and mode == "train"

    def leaf(path, l):
        shape = tuple(l.shape)
        start = 1 if _in_groups(path) else 0
        if len(shape) - start < 2:
            return replicated(mesh)  # scalars, norm gains, biases
        return NamedSharding(
            mesh, _leaf_param_spec(shape, mesh, fsdp=fsdp, start=start))

    return jax.tree_util.tree_map_with_path(leaf, tree)


def hier_momentum_sharding(mesh, tree: Pytree) -> Pytree:
    """Pod-sharded layout for stacked ``(G, ...)`` momentum buffers on a
    multi-pod mesh: parameter dims over ``pod`` (+ ``model`` when a second
    dim divides), leading group axis unsharded. This is EXACTLY the block
    layout ``dist.hierarchy``'s shard_map expects, so the robust train step's
    hierarchical distance pass reads the buffers in place — resharding (and
    any cross-pod momentum gather) never appears in the lowered HLO."""
    from repro.dist.hierarchy import momentum_pspec

    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, momentum_pspec(tuple(l.shape), mesh)), tree)


def batch_sharding(cfg: ModelConfig, mesh, tree: Pytree) -> Pytree:
    """Shard every batch leaf's leading dim over the data-parallel axes."""
    dp = dp_axes(mesh)

    def leaf(l):
        shape = tuple(l.shape)
        if len(shape) >= 1 and _fits(shape[0], mesh, dp):
            return NamedSharding(mesh, P(dp, *([None] * (len(shape) - 1))))
        return replicated(mesh)

    return jax.tree_util.tree_map(leaf, tree)


def cache_sharding(cfg: ModelConfig, mesh, tree: Pytree) -> Pytree:
    """KV / recurrent caches: batch axis over dp. Scanned cache stacks (under
    ``groups``) carry a leading n_full dim, so their batch dim is axis 1."""
    dp = dp_axes(mesh)

    def leaf(path, l):
        shape = tuple(l.shape)
        if len(shape) == 0:
            return replicated(mesh)            # cache["pos"]
        b_axis = 1 if (_in_groups(path) and len(shape) >= 2) else 0
        if _fits(shape[b_axis], mesh, dp):
            spec = [None] * len(shape)
            spec[b_axis] = dp
            return NamedSharding(mesh, P(*spec))
        return replicated(mesh)

    return jax.tree_util.tree_map_with_path(leaf, tree)
