"""Mesh-aware step factories: standard μ²-SGD training, robust data-parallel
training (paper Alg. 2's synchronous group form + Remark 3.1 weighting),
prefill and single-token serve.

Every factory returns a PURE function ``step(...) -> (..., metrics)`` suitable
for ``jax.jit`` — callers add shardings (launch/specs.py) and donation
(``donate_argnums=(0,)`` so the train state / KV cache updates in place). The
robust step keeps per-group corrected momenta as a STACKED pytree — leaves
carry a leading ``(n_groups, ...)`` axis — and aggregates through the unified
``repro.agg`` API, whose stacked branch (dist/robust.py) runs the CTMA/GM
distance pass once globally across leaves with no O(m·d) flatten copy; traced
under a multi-pod ``mesh_context`` that branch auto-upgrades to the
hierarchical cross-pod path (dist/hierarchy.py: pod-sharded momenta, distance
reductions as (m,)-sized psums over the pod axis — see dist/README.md for the
HBM + ICI accounting).

Byzantine group behaviors follow core.attacks (Appendix D), adapted to the
group setting: label_flip poisons a group's labels before its gradients;
sign_flip negates its transmitted momentum; little/empire are omniscient over
the honest groups' stacked buffers and their weights.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attacks import _little_zmax, flip_labels
from repro.models.config import ModelConfig
from repro.models.lm import chunk_step, decode_step, init_lm, lm_loss, prefill
from repro.optim.mu2sgd import (OptConfig, OptState, _project, init_opt,
                                opt_query_points, opt_update, server_step)
from repro.utils import global_norm

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map


class RobustDPConfig(NamedTuple):
    """Robust data-parallel group configuration (server side of Alg. 2)."""
    n_groups: int = 4
    agg: str = "ctma:cwmed"          # repro.agg spec: rule[:base][@backend]
    lam: float = 0.25                # λ for the meta-aggregator
    byz_groups: Tuple[int, ...] = ()
    byz_attack: str = "none"         # none | sign_flip | label_flip | little | empire
    weight_mode: str = "counts"      # counts (s_i = update counts) | batch_size
    group_sizes: Optional[Tuple[int, ...]] = None  # relative per-group batch rows
    attack_epsilon: float = 0.1      # empire scale
    attack_z_max: Optional[float] = None  # little deviation; None -> from weights


class TrainState(NamedTuple):
    opt: OptState
    D: Optional[Pytree] = None       # stacked per-group momentum, leaves (G, ...)
    counts: Optional[Array] = None   # (G,) per-group update counts s_t


def init_train_state(cfg: ModelConfig, opt_cfg: OptConfig, key,
                     robust: Optional[RobustDPConfig] = None) -> TrainState:
    params = init_lm(key, cfg)
    opt = init_opt(opt_cfg, params)
    if robust is None:
        return TrainState(opt=opt, D=None, counts=None)
    G = robust.n_groups
    D = _tmap(lambda p: jnp.zeros((G,) + p.shape, p.dtype), params)
    counts = jnp.zeros((G,), jnp.float32)
    return TrainState(opt=opt, D=D, counts=counts)


# ---------------------------------------------------------------------------
# Standard (single-group) train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig):
    """step(state, batch) -> (state, {loss, grad_norm}). μ²-SGD evaluates the
    gradient at BOTH query points on the same batch (the variance-reduced
    correction); momentum/sgd evaluate once at w."""

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)

    def step(state: TrainState, batch: dict):
        opt = state.opt
        xq, xprev = opt_query_points(opt_cfg, opt)
        loss, g = jax.value_and_grad(loss_fn)(xq, batch)
        g_tilde = jax.grad(loss_fn)(xprev, batch) if opt_cfg.name == "mu2" else None
        new_opt = opt_update(opt_cfg, opt, g, g_tilde)
        metrics = {"loss": loss, "grad_norm": global_norm(g)}
        return state._replace(opt=new_opt), metrics

    return step


# ---------------------------------------------------------------------------
# Robust data-parallel train step
# ---------------------------------------------------------------------------

def _group_sizes(rcfg: RobustDPConfig, B: int) -> list[int]:
    """Static per-group row counts summing to B (Remark 3.1 heterogeneity).

    Relative ``group_sizes`` are apportioned by largest remainder with a
    ≥1-row floor. (The previous ``sizes[-1] += B - sum(sizes)`` rescaling
    could drive the last group to zero or negative rows under skewed ratios —
    an empty slice whose loss is 0/0 = NaN.)"""
    G = rcfg.n_groups
    if rcfg.group_sizes is None:
        base, extra = divmod(B, G)
        assert base >= 1, f"batch {B} too small for {G} groups"
        return [base + (1 if i < extra else 0) for i in range(G)]
    gs = list(rcfg.group_sizes)
    assert len(gs) == G
    assert min(gs) >= 1, f"group_sizes ratios must be >= 1, got {gs}"
    assert B >= G, f"batch {B} too small for {G} groups with >=1 row each"
    total = sum(gs)
    if total == B:
        return gs
    quota = [B * g / total for g in gs]
    sizes = [max(1, int(q)) for q in quota]
    deficit = B - sum(sizes)
    if deficit > 0:       # hand out remaining rows by largest fractional part
        order = sorted(range(G), key=lambda i: quota[i] - int(quota[i]),
                       reverse=True)
        for k in range(deficit):
            sizes[order[k % G]] += 1
    elif deficit < 0:     # the >=1 floor over-allocated: shrink the groups
        order = sorted(range(G), key=lambda i: quota[i] - int(quota[i]))
        k = 0
        while deficit < 0:
            i = order[k % G]
            if sizes[i] > 1:
                sizes[i] -= 1
                deficit += 1
            k += 1
    assert sum(sizes) == B and min(sizes) >= 1, (sizes, B)
    return sizes


def _stack_trees(trees: list) -> Pytree:
    return _tmap(lambda *ls: jnp.stack(ls), *trees)


def _bcast(v: Array, leaf: Array) -> Array:
    """Reshape a (G,) vector for broadcasting against a (G, ...) leaf."""
    return v.reshape(v.shape + (1,) * (leaf.ndim - 1)).astype(jnp.float32)


def _apply_byz_attacks(rcfg: RobustDPConfig, D: Pytree, weights: Array) -> Pytree:
    """Transform the stacked transmitted momenta according to the attack."""
    name = rcfg.byz_attack
    if name in ("none", "label_flip") or not rcfg.byz_groups:
        return D
    G = rcfg.n_groups
    byz = jnp.zeros((G,), bool).at[jnp.asarray(rcfg.byz_groups)].set(True)
    if name == "sign_flip":
        sign = jnp.where(byz, -1.0, 1.0)
        return _tmap(lambda l: (l * _bcast(sign, l)).astype(l.dtype), D)

    # omniscient attacks: weighted mean/std over the HONEST groups
    hw = weights.astype(jnp.float32) * (~byz).astype(jnp.float32) + 1e-30
    hw_sum = jnp.sum(hw)

    def leaf_mean(l):
        return jnp.einsum("g,g...->...", hw, l.astype(jnp.float32)) / hw_sum

    mu = _tmap(leaf_mean, D)
    if name == "empire":
        atk = _tmap(lambda m_: -rcfg.attack_epsilon * m_, mu)
    elif name == "little":
        def leaf_std(l, m_):
            var = jnp.einsum("g,g...->...", hw,
                             jnp.square(l.astype(jnp.float32) - m_)) / hw_sum
            return jnp.sqrt(jnp.maximum(var, 0.0))

        sd = _tmap(leaf_std, D, mu)
        z = (jnp.asarray(rcfg.attack_z_max, jnp.float32)
             if rcfg.attack_z_max is not None
             else _little_zmax(jnp.sum(weights * (~byz)), jnp.sum(weights * byz)))
        atk = _tmap(lambda m_, s_: m_ - z * s_, mu, sd)
    else:
        raise KeyError(f"unknown attack: {name}")

    def splice(l, a):
        return jnp.where(_bcast(byz.astype(jnp.float32), l) > 0,
                         a[None].astype(l.dtype), l)

    return _tmap(splice, D, atk)


def make_robust_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                           rcfg: RobustDPConfig):
    """Synchronous robust-DP step: the global batch is split across
    ``n_groups`` groups; each computes its corrected momentum on its shard;
    Byzantine groups corrupt theirs; the server robust-aggregates the stacked
    buffers weighted per ``weight_mode`` and applies the AnyTime update."""
    from repro.agg import resolve

    # one resolve path with core.engine: the stacked momenta take the
    # leaf-wise global-distance-pass branch of the layout-polymorphic callable
    agg_fn = resolve(rcfg.agg, lam=rcfg.lam)
    G = rcfg.n_groups
    label_flip_on = (rcfg.byz_attack == "label_flip" and bool(rcfg.byz_groups))
    byz_list = list(rcfg.byz_groups)

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)

    def per_group(xq, xprev, gbatch, flip):
        if label_flip_on:
            lab = gbatch["labels"]
            lab = jnp.where(flip, flip_labels(lab, cfg.vocab), lab)
            gbatch = {**gbatch, "labels": lab}
        loss, g = jax.value_and_grad(loss_fn)(xq, gbatch)
        g_tilde = (jax.grad(loss_fn)(xprev, gbatch)
                   if opt_cfg.name == "mu2" else g)
        return loss, g, g_tilde

    def step(state: TrainState, batch: dict):
        opt = state.opt
        B = jax.tree_util.tree_leaves(batch)[0].shape[0]
        sizes = _group_sizes(rcfg, B)
        flip_flags = jnp.asarray([i in byz_list for i in range(G)])
        xq, xprev = opt_query_points(opt_cfg, opt)

        if len(set(sizes)) == 1:
            # uniform groups: ONE traced gradient, vmapped over the group axis
            gb = _tmap(lambda v: v.reshape((G, sizes[0]) + v.shape[1:]), batch)
            losses, g, g_tilde = jax.vmap(
                lambda b, f: per_group(xq, xprev, b, f))(gb, flip_flags)
        else:
            outs = []
            off = 0
            for i, sz in enumerate(sizes):
                gbatch = _tmap(lambda v: jax.lax.slice_in_dim(v, off, off + sz), batch)
                outs.append(per_group(xq, xprev, gbatch, flip_flags[i]))
                off += sz
            losses = jnp.stack([o[0] for o in outs])
            g = _stack_trees([o[1] for o in outs])
            g_tilde = _stack_trees([o[2] for o in outs])

        counts_new = state.counts + 1.0

        # per-group corrected momentum (μ²) / Polyak momentum / raw gradient
        if opt_cfg.name == "mu2":
            beta = (jnp.full((G,), opt_cfg.beta, jnp.float32)
                    if opt_cfg.beta is not None
                    else 1.0 / jnp.maximum(counts_new, 1.0))
            first = counts_new <= 1.0

            def corr(gl, dl, gtl):
                b = _bcast(beta, gl)
                upd = gl.astype(jnp.float32) + (1.0 - b) * (
                    dl.astype(jnp.float32) - gtl.astype(jnp.float32))
                return jnp.where(_bcast(first.astype(jnp.float32), gl) > 0,
                                 gl.astype(jnp.float32), upd).astype(dl.dtype)

            D_new = _tmap(corr, g, state.D, g_tilde)
        elif opt_cfg.name == "momentum":
            beta = 0.9 if opt_cfg.beta is None else opt_cfg.beta
            D_new = _tmap(lambda dl, gl: (beta * dl.astype(jnp.float32)
                                          + (1.0 - beta) * gl.astype(jnp.float32)
                                          ).astype(dl.dtype), state.D, g)
        else:  # sgd
            D_new = _tmap(lambda dl, gl: gl.astype(dl.dtype), state.D, g)

        size_w = jnp.asarray(sizes, jnp.float32)
        weights = counts_new if rcfg.weight_mode == "counts" else size_w

        D_new = _apply_byz_attacks(rcfg, D_new, weights)

        d_hat = agg_fn(D_new, weights)

        if opt_cfg.name == "mu2":
            new_opt = server_step(opt_cfg, opt, d_hat)
        else:
            # same decoupled weight decay as opt_update/server_step
            w = _tmap(lambda wl, dl: (wl - opt_cfg.lr * dl.astype(wl.dtype)
                                      - opt_cfg.lr * opt_cfg.weight_decay * wl),
                      opt.w, d_hat)
            w = _project(opt_cfg, w, opt.anchor)
            new_opt = OptState(w=w, x=w, x_prev=None, d=opt.d, t=opt.t + 1,
                               anchor=opt.anchor)

        loss = jnp.sum(losses * size_w) / jnp.sum(size_w)
        metrics = {"loss": loss, "grad_norm": global_norm(d_hat)}
        return TrainState(opt=new_opt, D=D_new, counts=counts_new), metrics

    return step


# ---------------------------------------------------------------------------
# Serve path
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, max_len: int):
    """step(params, batch) -> (logits, cache). Full forward over the prompt,
    emitting the ring-layout decode cache sized for ``max_len``."""

    def step(params, batch: dict):
        return prefill(params, cfg, batch, max_len)

    return step


def make_serve_step(cfg: ModelConfig):
    """step(params, cache, tokens) -> (logits (B,1,V), cache). Callers donate
    the cache (``donate_argnums=(1,)``) so the slice update is in-place."""

    def step(params, cache: dict, tokens: Array):
        return decode_step(params, cfg, cache, tokens)

    return step


def make_serve_prefill_step(cfg: ModelConfig, max_len: int):
    """step(params, batch, lens) -> (logits (B,1,V), cache). Exact
    right-padded prefill for the continuous-batching serve path: ``lens``
    ((B,) int32) carries each request's true length, the emitted cache rows
    match an unpadded prefill exactly (KV drop-scatter, dt-masked SSM state,
    gathered RG-LRU state — see models/lm.py), ``cache["pos"]`` is
    per-request, and logits cover ONLY each request's last real position."""

    def step(params, batch: dict, lens: Array):
        return prefill(params, cfg, batch, max_len, lens=lens)

    return step


def sample_tokens(logits: Array, keys: Array, temperature: float,
                  top_k: int = 0) -> Array:
    """Per-row token sampling. logits (B, V) float; keys (B, 2) uint32 raw
    PRNG keys (one per row — the serve engines derive them from the request
    uid and token index, so sampling is identical regardless of slot
    assignment or batch composition). temperature <= 0 → greedy argmax."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits.astype(jnp.float32) / temperature
    V = x.shape[-1]
    if top_k and top_k < V:
        kth = jax.lax.top_k(x, top_k)[0][..., -1:]
        x = jnp.where(x < kth, -jnp.inf, x)
    g = jax.vmap(lambda k, row: jax.random.gumbel(k, row.shape, jnp.float32))(keys, x)
    return jnp.argmax(x + g, axis=-1).astype(jnp.int32)


def sample_next(row_logits: Array, req_keys: Array, token_idx: Array,
                temperature: float, top_k: int = 0) -> Array:
    """THE sampling path for serving — first token and decode steps alike.
    row_logits (B, V); req_keys (B, 2) uint32 per-request keys; token_idx
    (B,) int32 index of the token being sampled within its request. The
    per-token key is fold_in(req_key, token_idx), which is what makes
    sampled streams independent of slot assignment, batch composition and
    arrival order. temperature <= 0 → greedy (keys/idx ignored)."""
    if temperature <= 0.0:
        return jnp.argmax(row_logits, axis=-1).astype(jnp.int32)
    keys = jax.vmap(jax.random.fold_in)(req_keys, token_idx)
    return sample_tokens(row_logits.astype(jnp.float32), keys, temperature,
                         top_k)


def make_decode_slots_step(cfg: ModelConfig, temperature: float = 0.0,
                           top_k: int = 0, paged: bool = False):
    """step(params, cache, tokens, req_keys, gen_idx[, page_table])
    -> (next_tokens, cache).

    One continuous-batching decode step over all S slots: ``cache["pos"]`` is
    the per-slot (S,) position vector, so slots at different depths decode in
    the same call. ``tokens`` (S, 1) int32 are the slots' current tokens;
    ``req_keys`` (S, 2) uint32 per-slot request PRNG keys and ``gen_idx``
    (S,) int32 per-slot generated-token indices drive sampling (ignored when
    temperature <= 0 — pass zeros). With ``paged=True`` the step takes the
    (S+1, pages_per_slot) int32 block table as a trailing argument and the
    cache is the paged layout (serve/cache.py); free slots' table rows point
    at the dump page, so their writes land in garbage. Callers donate the
    cache (``donate_argnums=(1,)``). Inactive slots decode garbage that the
    engine discards host-side; their rows never influence active slots
    (every op is row-independent; MoE capacity coupling is the documented
    exception — see serve/README.md)."""

    if paged:
        def step(params, cache: dict, tokens: Array, req_keys: Array,
                 gen_idx: Array, page_table: Array):
            logits, cache = decode_step(params, cfg, cache, tokens,
                                        page_table=page_table)
            nxt = sample_next(logits[:, 0], req_keys, gen_idx, temperature,
                              top_k)
            return nxt, cache
        return step

    def step(params, cache: dict, tokens: Array, req_keys: Array,
             gen_idx: Array):
        logits, cache = decode_step(params, cfg, cache, tokens)
        nxt = sample_next(logits[:, 0], req_keys, gen_idx, temperature, top_k)
        return nxt, cache

    return step


def make_unified_step(cfg: ModelConfig, temperature: float = 0.0,
                      top_k: int = 0, paged: bool = False):
    """step(params, cache, tokens, row_slots, row_lens, row_fresh, req_keys,
    tok_idx[, page_table]) -> (next_tokens (Rn,), cache).

    THE single jitted step of the chunked serve engine — it replaces the
    prefill → insert → decode trio: prefill chunks and decode rows share one
    ragged ``chunk_step`` call (models/lm.py), so the compile count is one
    per token-budget SHAPE CLASS — the mixed (S + chunk_rows, C) batch and
    the decode-only (S, 1) batch — independent of the workload's
    prompt-length mix. ``tok_idx`` (Rn,) int32 is each row's sampled-token
    index within its request (decode rows: gen_idx; a chunk row finishing
    its prompt: 0; non-final chunk rows: ignored — their sample is
    discarded host-side). Callers donate the cache
    (``donate_argnums=(1,)``)."""

    if paged:
        def step(params, cache: dict, tokens: Array, row_slots: Array,
                 row_lens: Array, row_fresh: Array, req_keys: Array,
                 tok_idx: Array, page_table: Array):
            logits, cache = chunk_step(params, cfg, cache, tokens, row_slots,
                                       row_lens, row_fresh,
                                       page_table=page_table)
            nxt = sample_next(logits[:, 0], req_keys, tok_idx, temperature,
                              top_k)
            return nxt, cache
        return step

    def step(params, cache: dict, tokens: Array, row_slots: Array,
             row_lens: Array, row_fresh: Array, req_keys: Array,
             tok_idx: Array):
        logits, cache = chunk_step(params, cfg, cache, tokens, row_slots,
                                   row_lens, row_fresh)
        nxt = sample_next(logits[:, 0], req_keys, tok_idx, temperature, top_k)
        return nxt, cache

    return step


# ---------------------------------------------------------------------------
# Replicated (Byzantine-tolerant) serve path
# ---------------------------------------------------------------------------

def make_replicated_prefill_step(cfg: ModelConfig, max_len: int):
    """step(params_stack, batch, lens) -> (logits (R, B, 1, V), cache_stack).

    One jitted call prefills the SAME bucketed prompt batch through all R
    replicas' parameters (stacked pytree, leaves (R, ...)), emitting the
    per-replica slot caches stacked on a leading replica axis."""

    def step(params_stack, batch: dict, lens: Array):
        return jax.vmap(
            lambda p: prefill(p, cfg, batch, max_len, lens=lens))(params_stack)

    return step


def vote_logits_fn(cfg, byz: Tuple[int, ...], n_replicas: int,
                   vote: str = "cwmed", lam: float = 0.25,
                   zeno_rho: float = 1e-3, collect_metrics: bool = False):
    """Build ``(logits (R, S, V), weights (R,), key) -> (voted (S, V),
    scores (R, S))`` — attack injection, robust vote, Zeno++-style pre-vote
    scores, shared by the replicated decode and first-token paths.
    ``collect_metrics`` (STATIC) appends a third output: the shape-static
    ``serve.vote.*`` telemetry dict (disagreement mass + vote margin per
    slot, repro.obs registry names) derived from the TRANSMITTED stack, so
    an attacked replica's dissent is visible even after the robust vote
    suppressed it.

    ``cfg`` is a :class:`repro.core.attacks.LogitAttackConfig`. The score of
    replica r on slot s is ``cos(l_rs, v_s) - rho·‖l_rs - v_s‖²/‖v_s‖²``
    against the robust anchor v (the ω-CWMed of the transmitted stack) — an
    agreeing replica scores ~1, a diverging one falls below 0; the engine
    quarantines on a host-side threshold. The anchor is the same trick as
    Zeno++'s oracle gradient: no trusted replica exists, so the robust vote
    itself is the validation oracle."""
    from repro.agg.logits import resolve_logits
    from repro.core.attacks import corrupt_logits

    vote_fn = resolve_logits(vote, lam=lam)
    anchor_fn = (vote_fn if getattr(vote_fn.spec, "canonical", vote) == "cwmed"
                 else resolve_logits("cwmed"))
    honest = jnp.asarray([i not in byz for i in range(n_replicas)])

    def run(logits: Array, weights: Array, key: Array):
        lg = corrupt_logits(cfg, logits.astype(jnp.float32), honest, weights,
                            key)
        # A zero-mass replica (dead / hanging / quarantined) must not be able
        # to touch the vote AT ALL — but a zero weight alone still lets its
        # row perturb ω-CWMed's tie-averaging (the sorted value between two
        # half-mass honest rows). Substitute unavailable rows with the
        # highest-mass replica's row, so every value in the voted stack comes
        # from a replica that actually holds mass.
        avail = weights > 0
        ref = jnp.take(lg, jnp.argmax(weights), axis=0)          # (S, V)
        lv = jnp.where(avail[:, None, None], lg, ref[None])
        v = anchor_fn(lv, weights)                               # (S, V)
        voted = v if anchor_fn is vote_fn else vote_fn(lv, weights)
        # scores come from the TRUE transmitted rows, so telemetry keeps
        # showing an excluded replica's divergence
        vnorm = jnp.sqrt(jnp.maximum(jnp.sum(jnp.square(v), -1), 1e-12))  # (S,)
        lnorm = jnp.sqrt(jnp.maximum(jnp.sum(jnp.square(lg), -1), 1e-12))
        inner = jnp.einsum("rsv,sv->rs", lg, v)
        dist2 = jnp.sum(jnp.square(lg - v[None]), -1)            # (R, S)
        scores = (inner / (lnorm * vnorm[None])
                  - zeno_rho * dist2 / jnp.square(vnorm)[None])
        if not collect_metrics:
            return voted, scores
        # vote telemetry (shape-static, derived-only): how much vote mass
        # dissented from the voted argmax, and how decisive the vote was
        mass = weights / jnp.maximum(jnp.sum(weights), 1e-30)      # (R,)
        tok = jnp.argmax(voted, axis=-1)                           # (S,)
        dissent = jnp.argmax(lv, axis=-1) != tok[None]             # (R, S)
        top2 = jax.lax.top_k(voted, 2)[0]                          # (S, 2)
        vmetrics = {
            "serve.vote.disagree_mass": jnp.sum(
                jnp.where(dissent, mass[:, None], 0.0), axis=0),   # (S,)
            "serve.vote.margin": top2[:, 0] - top2[:, 1],          # (S,)
        }
        return voted, scores, vmetrics

    return run


def make_replicated_decode_step(cfg: ModelConfig, n_replicas: int,
                                attack, byz: Tuple[int, ...] = (),
                                vote: str = "cwmed", lam: float = 0.25,
                                zeno_rho: float = 1e-3,
                                temperature: float = 0.0, top_k: int = 0,
                                paged: bool = False,
                                collect_metrics: bool = False):
    """step(params_stack, cache_stack, tokens, req_keys, gen_idx, weights,
    key[, page_table]) -> (next_tokens (S,), scores (R, S), cache_stack).

    One continuous-batching decode step for ALL R replicas × S slots: the
    per-replica decode is vmapped over the stacked params/cache (replica r's
    KV cache lives at leaf row r), Byzantine replicas corrupt their reported
    logits per ``attack`` (:class:`LogitAttackConfig`), and each slot's next
    token is sampled from the ``vote``-aggregated logits weighted by the
    runtime (R,) ``weights`` — staleness-derived masses with dead / hanging /
    quarantined replicas zeroed by the engine, so availability changes never
    recompile. ``scores`` are the Zeno++-style pre-vote scores the engine's
    quarantine policy consumes host-side. Every replica decodes the voted
    token regardless of its vote mass, which is what keeps a quarantined
    replica's KV cache coherent for re-admission.

    ``collect_metrics`` (STATIC) appends the ``serve.vote.*`` telemetry dict
    of :func:`vote_logits_fn` as a 4th output — derived values only, so the
    sampled token stream is identical either way and the default lowers to
    the uninstrumented HLO."""
    run_vote = vote_logits_fn(attack, byz, n_replicas, vote=vote, lam=lam,
                              zeno_rho=zeno_rho,
                              collect_metrics=collect_metrics)

    def body(params, cache, tokens, req_keys, gen_idx, weights, key,
             page_table=None):
        def one(p, c):
            return decode_step(p, cfg, c, tokens, page_table=page_table)

        logits, cache = jax.vmap(one)(params, cache)    # (R, S, 1, V)
        voted, scores, *vm = run_vote(logits[:, :, 0, :], weights, key)
        nxt = sample_next(voted, req_keys, gen_idx, temperature, top_k)
        if collect_metrics:
            return nxt, scores, cache, vm[0]
        return nxt, scores, cache

    if paged:
        def step(params, cache, tokens, req_keys, gen_idx, weights, key,
                 page_table):
            return body(params, cache, tokens, req_keys, gen_idx, weights,
                        key, page_table)
        return step

    def step(params, cache, tokens, req_keys, gen_idx, weights, key):
        return body(params, cache, tokens, req_keys, gen_idx, weights, key)

    return step


def make_replicated_unified_step(cfg: ModelConfig, n_replicas: int,
                                 attack, byz: Tuple[int, ...] = (),
                                 vote: str = "cwmed", lam: float = 0.25,
                                 zeno_rho: float = 1e-3,
                                 temperature: float = 0.0, top_k: int = 0,
                                 paged: bool = False,
                                 collect_metrics: bool = False):
    """step(params_stack, cache_stack, tokens, row_slots, row_lens,
    row_fresh, req_keys, tok_idx, weights, key[, page_table])
    -> (next_tokens (Rn,), scores (R, Rn), cache_stack).

    The replicated form of :func:`make_unified_step`: every replica runs the
    SAME ragged chunk batch through its own params/cache (vmapped stacked
    pytrees), Byzantine replicas corrupt their reported per-row logits, and
    each row's token is sampled from the robust vote — so chunked prefill
    AND decode inherit the f < R/2 masking guarantee in one call. Decode
    rows sit at columns 0..S-1 (row index == slot id), which is what keeps
    the engine's host-side quarantine indexing (`scores[r, active_slots]`)
    valid on mixed batches. ``collect_metrics`` (STATIC) appends the
    ``serve.vote.*`` telemetry dict exactly as in
    :func:`make_replicated_decode_step`."""
    run_vote = vote_logits_fn(attack, byz, n_replicas, vote=vote, lam=lam,
                              zeno_rho=zeno_rho,
                              collect_metrics=collect_metrics)

    def body(params, cache, tokens, row_slots, row_lens, row_fresh, req_keys,
             tok_idx, weights, key, page_table=None):
        def one(p, c):
            return chunk_step(p, cfg, c, tokens, row_slots, row_lens,
                              row_fresh, page_table=page_table)

        logits, cache = jax.vmap(one)(params, cache)    # (R, Rn, 1, V)
        voted, scores, *vm = run_vote(logits[:, :, 0, :], weights, key)
        nxt = sample_next(voted, req_keys, tok_idx, temperature, top_k)
        if collect_metrics:
            return nxt, scores, cache, vm[0]
        return nxt, scores, cache

    if paged:
        def step(params, cache, tokens, row_slots, row_lens, row_fresh,
                 req_keys, tok_idx, weights, key, page_table):
            return body(params, cache, tokens, row_slots, row_lens, row_fresh,
                        req_keys, tok_idx, weights, key, page_table)
        return step

    def step(params, cache, tokens, row_slots, row_lens, row_fresh, req_keys,
             tok_idx, weights, key):
        return body(params, cache, tokens, row_slots, row_lens, row_fresh,
                    req_keys, tok_idx, weights, key)

    return step
