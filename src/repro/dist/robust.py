"""Stacked-pytree robust aggregation — the distributed form of core.aggregators.

In the data-parallel train step, per-group updates arrive as a pytree whose
leaves carry a leading group axis ``(m, ...)`` — the natural layout of a
``vmap``-ed gradient or an all-gathered momentum buffer. Flattening that tree
into the (m, d) matrix the flat aggregators expect costs an extra O(m·d) HBM
copy per server step (plus the unflatten on the way out), which Remark 4.1's
bandwidth accounting cannot afford. These aggregators operate leaf-wise
in place instead and agree leaf-for-leaf with ``core.aggregators``:

- coordinate-wise rules (mean, cwmed) are exactly leaf-separable;
- the GM / CTMA distance pass is computed ONCE GLOBALLY — per-leaf partial
  squared norms are reduced into a single (m,) distance vector across all
  leaves (matching the flat ‖x_i - y‖ over the concatenated vector), and the
  resulting per-worker scalar weights are broadcast back into leaf-wise
  combines. No leaf is ever materialized twice.

HBM passes over the stacked tree X (d = total parameter count):
    stacked_mean    1     stacked_cwmed   1
    stacked_gm      1 + 2·iters (distance pass + reweighted combine per iter)
    stacked_ctma    base + 2  (global distance pass + trimmed combine)
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregators import weighted_cwmed, weighted_cwtm

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map


def _weights(s: Optional[Array], m: int) -> Array:
    if s is None:
        return jnp.ones((m,), jnp.float32)
    return s.astype(jnp.float32)


def _lead(tree: Pytree) -> int:
    """The (shared) leading group-axis size m of a stacked tree."""
    return jax.tree_util.tree_leaves(tree)[0].shape[0]


def _flat2(leaf: Array) -> Array:
    """View an (m, ...) leaf as (m, prod(...)) for coordinate-wise rules."""
    return leaf.reshape(leaf.shape[0], -1)


def stacked_sqdist(tree: Pytree, y: Pytree,
                   scale: Optional[Pytree] = None) -> Array:
    """Global squared distances ‖x_i - y‖² summed across ALL leaves -> (m,).

    This is THE single distance pass shared by stacked_gm and stacked_ctma —
    and, applied to local shards, by the hierarchical path (dist/hierarchy.py),
    whose optional per-leaf ``scale`` pytree makes its cross-pod psum count
    replicated leaves exactly once. Each leaf is read once, partial sums are
    (m,) scalars."""
    def leaf_part(x, yl, f=1.0):
        diff = _flat2(x).astype(jnp.float32) - yl.reshape(1, -1).astype(jnp.float32)
        return f * jnp.sum(jnp.square(diff), axis=1)

    mapped = (_tmap(leaf_part, tree, y) if scale is None
              else _tmap(leaf_part, tree, y, scale))
    return sum(jax.tree_util.tree_leaves(mapped))


def _combine(tree: Pytree, coef: Array, denom) -> Pytree:
    """Leaf-wise Σ_i coef_i x_i / denom with (m,) coefficients."""
    def leaf(x):
        out = jnp.einsum("m,md->d", coef, _flat2(x).astype(jnp.float32)) / denom
        return out.reshape(x.shape[1:])

    return _tmap(leaf, tree)


# ---------------------------------------------------------------------------
# Aggregators
# ---------------------------------------------------------------------------

def stacked_mean(tree: Pytree, s: Optional[Array] = None) -> Pytree:
    s = _weights(s, _lead(tree))
    return _combine(tree, s, jnp.sum(s))


def stacked_cwmed(tree: Pytree, s: Optional[Array] = None) -> Pytree:
    """ω-CWMed is coordinate-wise, hence exactly leaf-separable."""
    s = _weights(s, _lead(tree))

    def leaf(x):
        return weighted_cwmed(_flat2(x).astype(jnp.float32), s).reshape(x.shape[1:])

    return _tmap(leaf, tree)


def stacked_gm(tree: Pytree, s: Optional[Array] = None, *, iters: int = 32,
               eps: float = 1e-8) -> Pytree:
    """ω-GM via Weiszfeld with the distance pass computed once globally."""
    s = _weights(s, _lead(tree))
    y0 = stacked_cwmed(tree, s)

    def body(_, y):
        dist = jnp.sqrt(jnp.maximum(stacked_sqdist(tree, y), 0.0))
        invd = s / jnp.maximum(dist, eps)
        return _combine(tree, invd, jnp.sum(invd))

    return jax.lax.fori_loop(0, iters, body, y0)


def stacked_ctma(tree: Pytree, s: Optional[Array] = None, *, lam: float,
                 base: Callable[..., Pytree] = stacked_cwmed,
                 x0: Optional[Pytree] = None) -> Pytree:
    """ω-CTMA (Alg. 1) on a stacked tree: anchor via ``base``, ONE global
    distance pass across leaves, one m-element sort/prefix in XLA, one
    leaf-wise trimmed combine."""
    from repro.kernels.wctma_fused import trim_weights  # pure jnp, no Pallas

    s = _weights(s, _lead(tree))
    if x0 is None:
        x0 = base(tree, s)
    # squared distances order identically to distances — skip the sqrt
    kept, thresh = trim_weights(stacked_sqdist(tree, x0), s, lam)
    return _combine(tree, kept, jnp.maximum(thresh, 1e-30))


def stacked_cwtm(tree: Pytree, s: Optional[Array] = None, *,
                 lam: float = 0.25) -> Pytree:
    """ω-CWTM: coordinate-wise like cwmed, hence exactly leaf-separable."""
    s = _weights(s, _lead(tree))

    def leaf(x):
        return weighted_cwtm(_flat2(x).astype(jnp.float32), s,
                             lam=lam).reshape(x.shape[1:])

    return _tmap(leaf, tree)


def stacked_pairwise_sqdist(tree: Pytree,
                            scale: Optional[Pytree] = None) -> Array:
    """Global (m, m) pairwise squared distances in ONE pass over the tree
    (``scale`` as in :func:`stacked_sqdist` — the hierarchical path's per-leaf
    psum weights).

    Differences are formed directly (like the flat ``core.aggregators.krum``)
    rather than via the Gram identity ‖x_i‖² + ‖x_j‖² − 2⟨x_i,x_j⟩, whose
    float32 cancellation zeroes out small distances between large-norm rows —
    exactly the clustered-honest-momenta regime Krum ranks on."""
    def part(x, f=1.0):
        xf = _flat2(x).astype(jnp.float32)
        return f * jnp.sum(jnp.square(xf[:, None, :] - xf[None, :, :]), axis=-1)

    mapped = _tmap(part, tree) if scale is None else _tmap(part, tree, scale)
    return sum(jax.tree_util.tree_leaves(mapped))


def krum_select(d2: Array, n_byz: int = 1) -> Array:
    """Krum winner index from an (m, m) pairwise squared-distance matrix —
    shared by the stacked path here and the hierarchical path
    (dist/hierarchy.py), so the scoring can never drift between the two."""
    m = d2.shape[0]
    d2 = jnp.where(jnp.eye(m, dtype=bool), jnp.inf, d2)
    k = max(m - n_byz - 2, 1)
    scores = jnp.sum(jnp.sort(d2, axis=1)[:, :k], axis=1)
    return jnp.argmin(scores)


def stacked_krum(tree: Pytree, s: Optional[Array] = None, *,
                 n_byz: int = 1) -> Pytree:
    """Krum on a stacked tree: one global pairwise-distance pass, then the
    winning row sliced out leaf-wise (ignores weights — classical rule)."""
    i = krum_select(stacked_pairwise_sqdist(tree), n_byz)
    return _tmap(lambda x: x[i], tree)


# ---------------------------------------------------------------------------
# Legacy factory — deprecated shim over the unified registry
# ---------------------------------------------------------------------------

def make_stacked_aggregator(spec: str, lam: float = 0.0, **kw
                            ) -> Callable[[Pytree, Optional[Array]], Pytree]:
    """Deprecated: use :func:`repro.agg.resolve` — the resolved callable
    accepts stacked pytrees (this layer) AND flat ``(m, d)`` matrices."""
    warnings.warn("make_stacked_aggregator is deprecated; use "
                  "repro.agg.resolve(spec, lam=...) — the resolved callable "
                  "is layout-polymorphic", DeprecationWarning, stacklevel=2)
    from repro.agg import resolve
    return resolve(spec, lam=lam, **kw)
