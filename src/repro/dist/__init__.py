"""Distributed layer: stacked-pytree robust aggregation, mesh-aware train /
prefill / serve step factories, sharding policies and the active-mesh context.

See README.md in this directory for the API and HBM-pass accounting.
"""
from .context import current_mesh, mesh_context  # noqa: F401
from .robust import (  # noqa: F401
    make_stacked_aggregator,
    stacked_ctma,
    stacked_cwmed,
    stacked_cwtm,
    stacked_gm,
    stacked_krum,
    stacked_mean,
)
from .steps import (  # noqa: F401
    RobustDPConfig,
    TrainState,
    init_train_state,
    make_prefill_step,
    make_robust_train_step,
    make_serve_step,
    make_train_step,
)
