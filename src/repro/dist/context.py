"""Active-mesh context.

Model code deep inside a jitted step (e.g. the sharded MoE dispatch) needs to
know the mesh it is being lowered for, without threading a mesh argument
through every layer signature. ``mesh_context`` publishes it; ``current_mesh``
reads it (returning None outside any context, in which case callers fall back
to mesh-free code paths).

The stack is trace-time state (meshes are static at lowering), so a plain
module-level list is correct under jit; a re-entrant ``with`` nests properly.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator, Optional

_MESH_STACK: list = []


@contextmanager
def mesh_context(mesh) -> Iterator[None]:
    """Publish ``mesh`` as the active mesh for the duration of the block."""
    _MESH_STACK.append(mesh)
    try:
        yield
    finally:
        _MESH_STACK.pop()


def current_mesh() -> Optional[object]:
    """The innermost active mesh, or None outside any ``mesh_context``."""
    return _MESH_STACK[-1] if _MESH_STACK else None


def current_axis_size(name: str) -> int:
    """Size of a named axis on the active mesh (1 when absent or no mesh).

    The hierarchical aggregation layer (dist/hierarchy.py) dispatches on
    ``current_axis_size('pod')`` at trace time: > 1 means the stacked momenta
    are pod-sharded and the cross-pod distance psum path must be used."""
    mesh = current_mesh()
    if mesh is None or name not in getattr(mesh, "axis_names", ()):
        return 1
    return int(mesh.shape[name])
