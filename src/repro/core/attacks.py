"""Byzantine attack suite (paper Appendix D, weighted/asynchronous variants).

An attack produces the update a Byzantine worker sends to the parameter
server. The omniscient attacks (``little``, ``empire``) see the *honest*
workers' current momentum buffers and their weights, exactly as in the paper's
adaptation where means/stds are computed coordinate-wise *with respect to the
weights*.

Layout-polymorphic like ``repro.agg``: the buffers may be a flat ``(m, d)``
matrix or a stacked pytree with ``(m, ...)`` leaves — the weighted mean/std
are coordinate-wise, hence leaf-separable, and the little-is-enough deviation
``z_max`` depends only on scalar weight masses.

``label_flip`` is a data poisoning attack — it is applied inside the engine by
flipping the labels (y -> 9 - y) before the gradient computation, so it has no
entry here beyond the label transform helper.

INFERENCE-TIME attacks (``LOGIT_ATTACKS`` / :func:`corrupt_logits`) are the
serving-side counterpart used by ``repro.serve.replicated``: a Byzantine
decode REPLICA corrupts the per-token logits it reports to the vote instead
of a training update. The omniscient variants (``little``, ``empire``) read
the honest replicas' logit rows with their staleness weights — the same
weighted coordinate-wise statistics as the training attacks, per (slot,
vocab) coordinate. Dead / hanging replicas and stale checkpoints are not
logit transforms and are modeled by the replicated engine itself (vote-mass
masking and checkpoint lag).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map

ATTACKS = ("none", "sign_flip", "label_flip", "little", "empire")

# Inference-time (replicated-serving) fault types. ``corrupt`` injects
# large-magnitude noise into the replica's reported logits (corrupted
# activations / logits); the rest mirror the training attacks on the logit
# layout. ``dead`` / ``hang`` / ``stale`` live in the replicated engine: they
# are availability / checkpoint faults, not logit transforms.
LOGIT_ATTACKS = ("none", "corrupt", "sign_flip", "little", "empire")


class AttackConfig(NamedTuple):
    name: str = "none"
    epsilon: float = 0.1     # empire scale
    z_max: Optional[float] = None  # little deviation; None -> derived from weights
    n_classes: int = 10      # label flip: y -> (C-1) - y


class LogitAttackConfig(NamedTuple):
    """Inference-time fault a Byzantine decode replica applies to the logits
    it reports to the per-token vote (``repro.serve.replicated``)."""
    name: str = "none"
    epsilon: float = 1.0           # empire scale (logits are O(1): 1.0 bites)
    z_max: Optional[float] = None  # little deviation; None -> from weights
    noise_scale: float = 10.0      # corrupt: std of the injected logit noise


def flip_labels(y: Array, n_classes: int = 10) -> Array:
    return (n_classes - 1) - y


def _little_zmax(honest_weight: Array, byz_weight: Array) -> Array:
    """A-Little-Is-Enough deviation, computed on weight mass (the paper adapts
    z_max to update counts rather than worker counts).

    With n = total weight and b = Byzantine weight, the supporting mass is
    s = floor(n/2 + 1) - b and z_max = Phi^{-1}((n - b - s) / (n - b)).
    """
    n = honest_weight + byz_weight
    s = jnp.floor(n / 2.0 + 1.0) - byz_weight
    phi = jnp.clip((n - byz_weight - s) / jnp.maximum(n - byz_weight, 1e-9), 1e-4, 1.0 - 1e-4)
    return ndtri(phi)


def weighted_honest_stats(honest_d: Pytree, honest_mask: Array,
                          weights: Array) -> tuple[Pytree, Pytree]:
    """Weighted coordinate-wise (mean, std) over the HONEST workers' buffers —
    the statistics every omniscient attack (static little/empire here, the
    adaptive attackers in ``repro.fleet.adaptive``) builds its vector from.
    Layout-polymorphic: ``honest_d`` is a flat ``(m, d)`` matrix or a stacked
    pytree with ``(m, ...)`` leaves."""
    hw = (weights * honest_mask.astype(jnp.float32) + 1e-30).astype(jnp.float32)
    hw_sum = jnp.sum(hw)

    def leaf_mean(l):
        return jnp.einsum("m,m...->...", hw, l.astype(jnp.float32)) / hw_sum

    mu = _tmap(leaf_mean, honest_d)

    def leaf_std(l, m_):
        var = jnp.einsum("m,m...->...", hw,
                         jnp.square(l.astype(jnp.float32) - m_)) / hw_sum
        return jnp.sqrt(jnp.maximum(var, 0.0))

    sd = _tmap(leaf_std, honest_d, mu)
    return mu, sd


def byzantine_vector(
    cfg: AttackConfig,
    honest_d: Pytree,         # (m, d) matrix OR stacked pytree: all buffers
    honest_mask: Array,       # (m,) bool — True for honest workers
    weights: Array,           # (m,) update counts s_t
    own_update: Pytree,       # (d,) vector / pytree an honest worker would send
) -> Pytree:
    """Return the Byzantine worker's transmitted update (same layout as
    ``own_update`` — flat vector or pytree)."""
    name = cfg.name
    if name in ("none", "label_flip"):
        # label_flip poisons the gradient upstream; the transmission is 'honest'
        return own_update
    if name == "sign_flip":
        return _tmap(jnp.negative, own_update)

    mu, sd = weighted_honest_stats(honest_d, honest_mask, weights)
    if name == "empire":
        return _tmap(lambda m_: -cfg.epsilon * m_, mu)
    if name == "little":
        if cfg.z_max is not None:
            z = jnp.asarray(cfg.z_max, jnp.float32)
        else:
            z = _little_zmax(jnp.sum(weights * honest_mask),
                             jnp.sum(weights * (~honest_mask)))
        return _tmap(lambda m_, s_: m_ - z * s_, mu, sd)
    raise KeyError(f"unknown attack: {name}")


def _bcast_rows(v: Array, x: Array) -> Array:
    """Reshape an (R,) vector for broadcasting against an (R, ...) array."""
    return v.reshape(v.shape + (1,) * (x.ndim - 1)).astype(jnp.float32)


def corrupt_logits(
    cfg: LogitAttackConfig,
    logits: Array,            # (R, S, V) per-replica per-slot logit rows
    honest_mask: Array,       # (R,) bool — True for honest replicas
    weights: Array,           # (R,) vote masses (staleness-derived)
    key: Array,               # PRNG key for the 'corrupt' noise draw
) -> Array:
    """Return the TRANSMITTED logit stack: honest rows pass through
    unchanged, Byzantine rows are replaced per ``cfg.name``.

    The omniscient attacks compute weighted mean/std over the honest
    replicas' rows per (slot, vocab) coordinate — the serving analogue of
    :func:`byzantine_vector`'s weighted statistics, with replicas in the
    worker role and staleness weights in the update-count role. All honest
    replicas fresh and identical drives the honest std to zero, so ``little``
    degenerates to the honest value — it only bites when honest replicas
    legitimately disagree (stale checkpoints)."""
    name = cfg.name
    if name == "none":
        return logits
    byz = _bcast_rows((~honest_mask).astype(jnp.float32), logits)
    xf = logits.astype(jnp.float32)
    if name == "sign_flip":
        return jnp.where(byz > 0, -xf, xf)
    if name == "corrupt":
        noise = cfg.noise_scale * jax.random.normal(key, xf.shape, jnp.float32)
        return jnp.where(byz > 0, xf + noise, xf)

    hw = (weights.astype(jnp.float32) * honest_mask.astype(jnp.float32)
          + 1e-30)
    hw_sum = jnp.sum(hw)
    mu = jnp.einsum("r,r...->...", hw, xf) / hw_sum
    if name == "empire":
        atk = -cfg.epsilon * mu
    elif name == "little":
        var = jnp.einsum("r,r...->...", hw, jnp.square(xf - mu)) / hw_sum
        sd = jnp.sqrt(jnp.maximum(var, 0.0))
        if cfg.z_max is not None:
            z = jnp.asarray(cfg.z_max, jnp.float32)
        else:
            z = _little_zmax(jnp.sum(weights * honest_mask),
                             jnp.sum(weights * (~honest_mask)))
        atk = mu - z * sd
    else:
        raise KeyError(f"unknown logit attack: {name}")
    return jnp.where(byz > 0, atk[None], xf)
