"""Byzantine attack suite (paper Appendix D, weighted/asynchronous variants).

An attack produces the vector a Byzantine worker sends to the parameter server.
The omniscient attacks (``little``, ``empire``) see the *honest* workers' current
momentum buffers and their weights, exactly as in the paper's adaptation where
means/stds are computed coordinate-wise *with respect to the weights*.

``label_flip`` is a data poisoning attack — it is applied inside the engine by
flipping the labels (y -> 9 - y) before the gradient computation, so it has no
entry here beyond the label transform helper.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
from jax.scipy.special import ndtri

from .aggregators import weighted_mean, weighted_std

Array = jnp.ndarray

ATTACKS = ("none", "sign_flip", "label_flip", "little", "empire")


class AttackConfig(NamedTuple):
    name: str = "none"
    epsilon: float = 0.1     # empire scale
    z_max: Optional[float] = None  # little deviation; None -> derived from weights
    n_classes: int = 10      # label flip: y -> (C-1) - y


def flip_labels(y: Array, n_classes: int = 10) -> Array:
    return (n_classes - 1) - y


def _little_zmax(honest_weight: Array, byz_weight: Array) -> Array:
    """A-Little-Is-Enough deviation, computed on weight mass (the paper adapts
    z_max to update counts rather than worker counts).

    With n = total weight and b = Byzantine weight, the supporting mass is
    s = floor(n/2 + 1) - b and z_max = Phi^{-1}((n - b - s) / (n - b)).
    """
    n = honest_weight + byz_weight
    s = jnp.floor(n / 2.0 + 1.0) - byz_weight
    phi = jnp.clip((n - byz_weight - s) / jnp.maximum(n - byz_weight, 1e-9), 1e-4, 1.0 - 1e-4)
    return ndtri(phi)


def byzantine_vector(
    cfg: AttackConfig,
    honest_d: Array,          # (m, d) current momentum buffers (all workers)
    honest_mask: Array,       # (m,) bool — True for honest workers
    weights: Array,           # (m,) update counts s_t
    own_update: Array,        # (d,) the vector an honest worker would send
) -> Array:
    """Return the Byzantine worker's transmitted vector."""
    name = cfg.name
    if name in ("none", "label_flip"):
        # label_flip poisons the gradient upstream; the transmission is 'honest'
        return own_update
    if name == "sign_flip":
        return -own_update

    hm = honest_mask.astype(honest_d.dtype)
    hw = weights * hm
    mu = weighted_mean(honest_d, hw + 1e-30)
    if name == "empire":
        return -cfg.epsilon * mu
    if name == "little":
        sd = weighted_std(honest_d, hw + 1e-30)
        if cfg.z_max is not None:
            z = jnp.asarray(cfg.z_max, honest_d.dtype)
        else:
            z = _little_zmax(jnp.sum(hw), jnp.sum(weights * (1.0 - hm)))
        return mu - z * sd
    raise KeyError(f"unknown attack: {name}")
