"""Weighted robust aggregation rules (paper Section 3).

All aggregators operate on a stacked matrix ``X`` of shape ``(m, d)`` — one row
per worker — and a weight vector ``s`` of shape ``(m,)`` (``None`` means equal
weights, recovering the classical unweighted rules). Every function returns a
``(d,)`` vector and is jit/vmap friendly (static shapes, no data-dependent
python control flow).

Implemented rules
-----------------
- ``weighted_mean``                      — baseline (non-robust).
- ``weighted_cwmed``   (ω-CWMed)         — Lemma C.3, c_λ = (1 + λ/(1-2λ))².
- ``weighted_gm``      (ω-GM / ω-RFA)    — Lemma C.1, Weiszfeld iterations.
- ``weighted_cwtm``    (ω-CWTM)          — weighted coordinate-wise trimmed mean.
- ``weighted_ctma``    (ω-CTMA, Alg. 1)  — meta-aggregator, c_λ ≤ 60λ(1+c_λ^base).
- ``krum`` / ``bucketing``               — unweighted baselines from prior work.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def _tie_tol(cw: Array, half: Array) -> Array:
    """Tolerance for the exact-tie rule: a float32 cumsum of m weights carries
    up to ~m·eps relative rounding, so an exact (atol=0) comparison misses
    genuine ties once prefix sums round (e.g. integer-valued weights past
    2^24). Scale the tolerance with the prefix length and the half-mass."""
    m = cw.shape[0]
    return 4.0 * m * jnp.finfo(cw.dtype).eps * jnp.abs(half)


def _weights(s: Optional[Array], m: int, dtype=jnp.float32) -> Array:
    if s is None:
        return jnp.ones((m,), dtype)
    return s.astype(dtype)


# ---------------------------------------------------------------------------
# Weighted mean / std (also used by the omniscient attacks)
# ---------------------------------------------------------------------------

def weighted_mean(x: Array, s: Optional[Array] = None) -> Array:
    s = _weights(s, x.shape[0], x.dtype)
    return jnp.einsum("m,md->d", s, x) / jnp.sum(s)


def weighted_std(x: Array, s: Optional[Array] = None) -> Array:
    """Coordinate-wise weighted standard deviation."""
    s = _weights(s, x.shape[0], x.dtype)
    mu = weighted_mean(x, s)
    var = jnp.einsum("m,md->d", s, jnp.square(x - mu)) / jnp.sum(s)
    return jnp.sqrt(jnp.maximum(var, 0.0))


# ---------------------------------------------------------------------------
# ω-CWMed — weighted coordinate-wise median
# ---------------------------------------------------------------------------

def weighted_median_1d(v: Array, s: Array) -> Array:
    """Weighted median of a vector ``v`` (shape (m,)) with weights ``s``.

    Definition from the paper: with values sorted ascending and weights carried
    along, pick the first j with cum(s) > S/2; if a prefix hits exactly S/2,
    average elements j and j+1.
    """
    order = jnp.argsort(v)
    vs = v[order]
    ws = s[order]
    cw = jnp.cumsum(ws)
    half = 0.5 * cw[-1]
    jstar = jnp.argmax(cw > half)  # first index strictly past half
    med = vs[jstar]
    # tie handling (mostly relevant for integer weights); the tolerance is
    # relative — see _tie_tol — because the f32 cumsum rounds
    tol = _tie_tol(cw, half)
    tie = jnp.any(jnp.abs(cw[:-1] - half) <= tol)
    jtie = jnp.argmax(jnp.abs(cw - half) <= tol)
    tied = 0.5 * (vs[jtie] + vs[jnp.minimum(jtie + 1, v.shape[0] - 1)])
    return jnp.where(tie, tied, med)


def weighted_cwmed(x: Array, s: Optional[Array] = None) -> Array:
    """ω-CWMed: weighted median applied independently per coordinate."""
    m, _ = x.shape
    s = _weights(s, m, x.dtype)
    order = jnp.argsort(x, axis=0)                      # (m, d)
    xs = jnp.take_along_axis(x, order, axis=0)          # sorted values
    ws = s[order]                                       # weights in sorted order
    cw = jnp.cumsum(ws, axis=0)
    half = 0.5 * cw[-1]
    past = cw > half
    jstar = jnp.argmax(past, axis=0)                    # (d,)
    med = jnp.take_along_axis(xs, jstar[None], axis=0)[0]
    tol = _tie_tol(cw, half)                            # (d,) relative tol
    tie = jnp.any(jnp.abs(cw[:-1] - half) <= tol, axis=0)
    jtie = jnp.argmax(jnp.abs(cw - half) <= tol, axis=0)
    vj = jnp.take_along_axis(xs, jtie[None], axis=0)[0]
    vj1 = jnp.take_along_axis(xs, jnp.minimum(jtie + 1, m - 1)[None], axis=0)[0]
    return jnp.where(tie, 0.5 * (vj + vj1), med)


# ---------------------------------------------------------------------------
# ω-GM — weighted geometric median via smoothed Weiszfeld
# ---------------------------------------------------------------------------

def weighted_gm(
    x: Array,
    s: Optional[Array] = None,
    *,
    iters: int = 32,
    eps: float = 1e-8,
) -> Array:
    """ω-GM: argmin_y Σ_i s_i ||y - x_i||, by eps-smoothed Weiszfeld iteration.

    Initialized at the weighted coordinate-wise median (robust anchor) so a
    single wild Byzantine row cannot dominate the first iterate.
    """
    m, _ = x.shape
    s = _weights(s, m, x.dtype)
    y0 = weighted_cwmed(x, s)

    def body(_, y):
        dist = jnp.sqrt(jnp.maximum(jnp.sum(jnp.square(x - y), axis=1), 0.0))
        invd = s / jnp.maximum(dist, eps)
        return jnp.einsum("m,md->d", invd, x) / jnp.sum(invd)

    return jax.lax.fori_loop(0, iters, body, y0)


# ---------------------------------------------------------------------------
# ω-CWTM — weighted coordinate-wise trimmed mean
# ---------------------------------------------------------------------------

def weighted_cwtm(x: Array, s: Optional[Array] = None, *, lam: float = 0.25) -> Array:
    """Trim λ weight-mass from each tail per coordinate, weighted-average the rest.

    Per coordinate, with sorted values and cumulative weights ``cum``, element i
    keeps the overlap of its weight interval [cum_{i-1}, cum_i] with the
    retained band [λS, (1-λ)S].
    """
    m, _ = x.shape
    s = _weights(s, m, x.dtype)
    order = jnp.argsort(x, axis=0)
    xs = jnp.take_along_axis(x, order, axis=0)
    ws = s[order]
    cum = jnp.cumsum(ws, axis=0)
    total = cum[-1]
    lo, hi = lam * total, (1.0 - lam) * total
    prev = jnp.concatenate([jnp.zeros_like(cum[:1]), cum[:-1]], axis=0)
    kept = jnp.clip(jnp.minimum(cum, hi) - jnp.maximum(prev, lo), 0.0, None)
    return jnp.sum(kept * xs, axis=0) / jnp.maximum(jnp.sum(kept, axis=0), 1e-30)


# ---------------------------------------------------------------------------
# ω-CTMA — Weighted Centered Trimmed Meta Aggregator (Algorithm 1)
# ---------------------------------------------------------------------------

def weighted_ctma(
    x: Array,
    s: Optional[Array] = None,
    *,
    lam: float,
    base: Callable[..., Array] = weighted_cwmed,
    x0: Optional[Array] = None,
) -> Array:
    """Algorithm 1. Anchors at a weighted-robust aggregate ``x0`` (computed with
    ``base`` unless given), keeps the (1-λ) weight-mass of rows closest to the
    anchor (clipping the boundary row's weight so the kept mass is exactly
    (1-λ)·Σs), and returns their weighted average.
    """
    m, _ = x.shape
    s = _weights(s, m, x.dtype)
    if x0 is None:
        x0 = base(x, s)
    dist = jnp.sqrt(jnp.maximum(jnp.sum(jnp.square(x - x0), axis=1), 0.0))
    order = jnp.argsort(dist)
    xs = x[order]
    ws = s[order]
    cum = jnp.cumsum(ws)
    thresh = (1.0 - lam) * cum[-1]
    prev = jnp.concatenate([jnp.zeros_like(cum[:1]), cum[:-1]])
    kept = jnp.clip(thresh - prev, 0.0, ws)  # per-row retained weight mass
    return jnp.einsum("m,md->d", kept, xs) / jnp.maximum(thresh, 1e-30)


# ---------------------------------------------------------------------------
# Unweighted baselines from prior work (for benchmark comparisons)
# ---------------------------------------------------------------------------

def krum(x: Array, s: Optional[Array] = None, *, n_byz: int = 1) -> Array:
    """Krum (Blanchard et al. 2017) — ignores weights (classical rule)."""
    m = x.shape[0]
    d2 = jnp.sum(jnp.square(x[:, None, :] - x[None, :, :]), axis=-1)  # (m, m)
    d2 = jnp.where(jnp.eye(m, dtype=bool), jnp.inf, d2)  # exclude self
    k = max(m - n_byz - 2, 1)
    nearest = jnp.sort(d2, axis=1)[:, :k]
    scores = jnp.sum(nearest, axis=1)
    return x[jnp.argmin(scores)]


def bucketing(
    x: Array,
    s: Optional[Array] = None,
    *,
    bucket: int = 2,
    inner: Callable[..., Array] = weighted_cwmed,
    key: Optional[jax.Array] = None,
) -> Array:
    """Bucketing meta-rule (Karimireddy et al. 2020): random buckets are
    averaged, then the inner rule aggregates bucket means. Used as the BASGDm
    style baseline in benchmarks."""
    m, d = x.shape
    s = _weights(s, m, x.dtype)
    perm = jnp.arange(m) if key is None else jax.random.permutation(key, m)
    pad = (-m) % bucket
    xp = jnp.concatenate([x[perm], jnp.zeros((pad, d), x.dtype)], axis=0)
    sp = jnp.concatenate([s[perm], jnp.zeros((pad,), s.dtype)], axis=0)
    nb = xp.shape[0] // bucket
    xb = xp.reshape(nb, bucket, d)
    sb = sp.reshape(nb, bucket)
    bw = jnp.sum(sb, axis=1)
    bx = jnp.einsum("nb,nbd->nd", sb, xb) / jnp.maximum(bw, 1e-30)[:, None]
    return inner(bx, bw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def c_lambda(name: str, lam: float) -> float:
    """Theoretical robustness coefficients from Table 1."""
    base = (1.0 + lam / max(1.0 - 2.0 * lam, 1e-9)) ** 2
    if name in ("gm", "cwmed"):
        return base
    if name.startswith("ctma"):
        return 60.0 * lam * (1.0 + base)
    raise KeyError(name)


def make_aggregator(spec: str, lam: float = 0.0, **kw) -> Callable[[Array, Optional[Array]], Array]:
    """Deprecated: use ``repro.agg.resolve(spec, lam=...)`` — the resolved
    callable keeps the pure-jnp semantics on flat ``(m, d)`` inputs (backend
    ``jnp``) and additionally accepts stacked pytrees."""
    warnings.warn("make_aggregator is deprecated; use "
                  "repro.agg.resolve(spec, lam=...)",
                  DeprecationWarning, stacklevel=2)
    from repro.agg import resolve
    return resolve(spec, lam=lam, backend="jnp", **kw)


AGGREGATOR_SPECS = ("mean", "cwmed", "gm", "cwtm", "krum", "ctma:cwmed", "ctma:gm", "bucketing:cwmed")
