"""The paper's primary contribution: weighted robust aggregation + async robust μ²-SGD."""
from .aggregators import (  # noqa: F401
    AGGREGATOR_SPECS,
    bucketing,
    c_lambda,
    krum,
    make_aggregator,
    weighted_ctma,
    weighted_cwmed,
    weighted_cwtm,
    weighted_gm,
    weighted_mean,
    weighted_median_1d,
    weighted_std,
)
from .attacks import (ATTACKS, LOGIT_ATTACKS, AttackConfig,  # noqa: F401
                      LogitAttackConfig, byzantine_vector, corrupt_logits,
                      flip_labels, weighted_honest_stats)
from .engine import (  # noqa: F401
    AsyncByzantineEngine,
    EngineConfig,
    EngineState,
    arrival_probs,
    byz_mask_array,
    engine_init,
    engine_step,
    expected_lambda,
    make_step_fn,
    stack_engine_states,
    unstack_engine_state,
)
