"""Asynchronous Byzantine parameter-server simulator — paper Algorithm 2.

Faithful event-driven reproduction: one worker arrives per server iteration
(sampled from an arrival distribution or round-robin), delivers its corrected
momentum ``d_t^{(i)}``, the server robust-aggregates ALL workers' latest
buffers weighted by their update counts ``s_t^{(i)}``, applies the AnyTime
update, and hands the worker the fresh query point.

PYTREE-NATIVE state: the model parameters are an arbitrary pytree, and the
per-worker buffers are STACKED pytrees whose leaves carry a leading worker
axis ``(m, ...)`` — the same layout as ``dist.steps``, so both paths
aggregate through the one layout-polymorphic ``repro.agg`` API (and the
fused Pallas kernels apply to both). A flat ``(d,)`` parameter vector is
simply the single-leaf case — the thin shim the paper-CNN experiments use:
every state field then stays a plain array, exactly the legacy layout.

State layout (leaves shown for a flat (d,)-vector model):
    w, x            (d,)    iterate / AnyTime average (query point)
    D               (m, d)  latest momentum from each worker (Alg. 2 line 5)
    S               (m,)    update counts s_t^{(i)}  (the aggregation weights)
    Xq              (m, d)  last query point handed to each worker (for g̃)
    t, t_byz        ()      iteration counters (λ accounting, Eq. 6)

The whole server iteration is a single jitted step. Byzantine behaviors follow
Appendix D: label flipping poisons the worker's labels before the gradient;
sign flipping negates the transmission; little/empire are omniscient and read
the honest workers' buffers with their weights.

VMAPPABLE CORE: the step body lives in the module-level :func:`engine_step`
(built via :func:`make_step_fn`), a pure function of
``(state, batch, probs, byz_mask)`` — everything that varies *per scenario*
without changing the trace (arrival probabilities, which workers are
Byzantine, aggregation-weight masking) is a traced argument, so
``repro.fleet`` vmaps ONE compiled step over a leading scenario axis of
:func:`stack_engine_states`-stacked states. :class:`AsyncByzantineEngine` is
the sequential (single-scenario) driver over the same body.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .attacks import AttackConfig, byzantine_vector, flip_labels
from ..optim.mu2sgd import OptConfig, anytime_coeff

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map


def _row(tree: Pytree, i) -> Pytree:
    """Slice worker i's row out of a stacked tree."""
    return _tmap(lambda l: l[i], tree)


def _set_row(tree: Pytree, i, val: Pytree) -> Pytree:
    return _tmap(lambda l, v: l.at[i].set(v), tree, val)


class EngineConfig(NamedTuple):
    m: int                                  # number of workers
    byz: tuple                              # tuple of Byzantine worker ids
    attack: AttackConfig = AttackConfig()
    agg: str = "ctma:cwmed"                 # repro.agg spec: rule[:base][@backend]
    lam: float = 0.2                        # λ for the meta-aggregator / trimming
    opt: OptConfig = OptConfig(name="mu2", lr=0.01, gamma=0.1, beta=0.25)
    arrival: str = "proportional"           # proportional | squared | uniform | round_robin
    byz_start_step: int = 0                 # attacks activate after this iteration
    n_classes: int = 10
    seed: int = 0
    # Flat-matrix aggregation backend (repro.agg): the server aggregation is
    # O(m·d) over the full momentum buffer every iteration — far from free at
    # production d. A backend embedded in ``agg`` ("ctma:gm@pallas") wins.
    #   auto   — fused Pallas kernels on TPU, jnp oracle elsewhere
    #   pallas — force the fused kernel path (interpret mode off-TPU)
    #   jnp    — force the pure-jnp aggregators
    agg_backend: str = "auto"

    def validate(self) -> "EngineConfig":
        """Reject degenerate worker/Byzantine configurations at construction
        time instead of letting them silently index-wrap inside the jitted
        step (negative ids), double-count one worker's buffer in the Byzantine
        mass (duplicate ids), or run a fleet with no honest worker at all
        (byz covering every id) — each of those trained to garbage without an
        error before this check."""
        if self.m < 1:
            raise ValueError(f"EngineConfig.m must be >= 1, got {self.m}")
        ids = [int(i) for i in self.byz]
        bad = [i for i in ids if not 0 <= i < self.m]
        if bad:
            raise ValueError(
                f"EngineConfig.byz ids {bad} out of range(m={self.m}) — "
                f"negative or >= m ids would index-wrap into other workers' "
                f"buffers")
        if len(set(ids)) != len(ids):
            dupes = sorted({i for i in ids if ids.count(i) > 1})
            raise ValueError(
                f"EngineConfig.byz contains duplicate ids {dupes} — each "
                f"worker is Byzantine at most once")
        if len(ids) >= self.m:
            raise ValueError(
                f"EngineConfig.byz covers all {self.m} workers — at least "
                f"one honest worker is required (the omniscient attacks and "
                f"the robust-aggregation guarantees are undefined otherwise)")
        return self


class EngineState(NamedTuple):
    w: Pytree
    x: Pytree
    D: Pytree
    S: Array
    Xq: Pytree
    t: Array
    t_byz: Array
    key: Array


def arrival_probs(cfg: EngineConfig) -> np.ndarray:
    ids = np.arange(1, cfg.m + 1, dtype=np.float64)
    if cfg.arrival == "proportional":
        p = ids
    elif cfg.arrival == "squared":
        p = ids ** 2
    elif cfg.arrival in ("uniform", "round_robin"):
        p = np.ones_like(ids)
    else:
        raise KeyError(cfg.arrival)
    return (p / p.sum()).astype(np.float32)


def expected_lambda(cfg: EngineConfig) -> float:
    """Expected fraction of Byzantine updates under the arrival distribution."""
    p = arrival_probs(cfg)
    return float(sum(p[i] for i in cfg.byz))


def byz_mask_array(m: int, byz: Sequence[int]) -> np.ndarray:
    """(m,) bool mask — True on Byzantine ids."""
    mask = np.zeros((m,), bool)
    for i in byz:
        mask[i] = True
    return mask


def stack_engine_states(states: Sequence[EngineState]) -> EngineState:
    """Stack per-scenario states along a NEW leading scenario axis — the
    layout ``repro.fleet`` vmaps :func:`engine_step` over."""
    return _tmap(lambda *ls: jnp.stack(ls), *states)


def unstack_engine_state(state: EngineState, i: int) -> EngineState:
    """Slice scenario ``i``'s row back out of a stacked fleet state."""
    return _tmap(lambda l: l[i], state)


def engine_init(cfg: EngineConfig, grad_fn: Callable, params: Pytree,
                init_batches: Any, byz_mask: Array) -> EngineState:
    """Alg. 2 line 2 as a pure function: every worker computes d_1 at x_1 on
    its own sample. ``byz_mask`` is explicit so fleet scenarios that share one
    compiled step can differ in WHICH workers are Byzantine."""
    x1 = _tmap(jnp.asarray, params)
    byz_mask = jnp.asarray(byz_mask)

    def one(i, batch):
        lk = "y" if "y" in batch else "labels"
        y = batch[lk]
        y = jnp.where(byz_mask[i] & (cfg.attack.name == "label_flip")
                      & (cfg.byz_start_step <= 0),
                      flip_labels(y, cfg.n_classes), y)
        return grad_fn(x1, {**batch, lk: y})

    D = jax.vmap(one, in_axes=(0, 0))(jnp.arange(cfg.m), init_batches)
    if cfg.attack.name == "sign_flip" and cfg.byz_start_step <= 0:
        def flip(l):
            byz = byz_mask.reshape((cfg.m,) + (1,) * (l.ndim - 1))
            return jnp.where(byz, -l, l)

        D = _tmap(flip, D)
    S = jnp.zeros((cfg.m,), jnp.float32)
    Xq = _tmap(lambda l: jnp.broadcast_to(l, (cfg.m,) + l.shape).copy(), x1)
    return EngineState(
        w=_tmap(lambda l: l.copy(), x1), x=_tmap(lambda l: l.copy(), x1),
        D=D, S=S, Xq=Xq,
        t=jnp.zeros((), jnp.int32), t_byz=jnp.zeros((), jnp.int32),
        key=jax.random.PRNGKey(cfg.seed),
    )


def engine_step(cfg: EngineConfig, value_grad_fn: Callable, grad_fn: Callable,
                agg_fn: Callable, attack_fn: Callable,
                state: EngineState, batch: Any, probs: Array, byz_mask: Array,
                *, anchor: Optional[Pytree] = None,
                weighted: Optional[Array] = None,
                per_worker_batch: bool = False,
                collect_metrics: bool = False) -> tuple[EngineState, dict]:
    """ONE server iteration (Alg. 2 lines 4-10) as a pure, vmappable function.

    Traced per-scenario arguments (vmap these over a leading scenario axis):
      state      the :class:`EngineState` pytree (stacked for fleets).
      batch      the arriving sample; with ``per_worker_batch`` the leaves
                 carry a leading worker axis ``(m, ...)`` and the step selects
                 the arriving worker's row — the data-heterogeneity path.
      probs      (m,) arrival probabilities (ignored under round_robin).
      byz_mask   (m,) bool — True on Byzantine workers.
      weighted   optional () bool — False replaces the aggregation weights
                 with ones (the non-weighted-rule ablation) WITHOUT leaving
                 the compile group; None (static) keeps the weighted rule.

    Static (compile-signature) arguments: ``cfg`` (attack name, arrival kind,
    optimizer, spec string), the grad/aggregate/attack callables, ``anchor``
    presence and ``per_worker_batch``. ``attack_fn(D, honest_mask, weights,
    own_update)`` defaults to :func:`repro.core.attacks.byzantine_vector`;
    ``repro.fleet.adaptive`` substitutes attackers that tune against
    ``agg_fn`` here.

    ``collect_metrics`` (STATIC) additionally returns the ``engine.*``
    telemetry pytree (repro.obs registry names: per-worker weight mass +
    histogram, Byzantine mass seen by the rule, robust-aggregate vs
    weighted-mean anchor distance) as shape-static extra metrics entries —
    derived values only, so the trained trajectory is bit-identical either
    way, and False (the default) lowers to the uninstrumented HLO."""
    opt = cfg.opt
    key, k_arrival = jax.random.split(state.key)

    t_next = state.t + 1
    if cfg.arrival == "round_robin":
        i = (state.t % cfg.m).astype(jnp.int32)
    else:
        i = jax.random.categorical(k_arrival, jnp.log(probs))

    is_byz = byz_mask[i] & (t_next > cfg.byz_start_step)

    # --- worker computation (lines 8-10) -------------------------------
    if per_worker_batch:
        batch = _tmap(lambda l: l[i], batch)
    label_key = "y" if "y" in batch else "labels"
    y = batch[label_key]
    y_used = jnp.where(is_byz & (cfg.attack.name == "label_flip"),
                       flip_labels(y, cfg.n_classes), y)
    batch_used = {**batch, label_key: y_used}

    query = state.x if opt.name == "mu2" else state.w
    loss, g = value_grad_fn(query, batch_used)

    s_new = state.S[i] + 1.0
    d_prev = _row(state.D, i)
    if opt.name == "mu2":
        g_tilde = grad_fn(_row(state.Xq, i), batch_used)  # same sample z_t
        beta = (jnp.asarray(opt.beta, jnp.float32) if opt.beta is not None
                else 1.0 / jnp.maximum(s_new, 1.0))
        d_honest = _tmap(
            lambda gl, dl, gtl: jnp.where(s_new <= 1.0, gl,
                                          gl + (1.0 - beta) * (dl - gtl)),
            g, d_prev, g_tilde)
    elif opt.name == "momentum":
        beta = 0.9 if opt.beta is None else opt.beta
        d_honest = _tmap(lambda dl, gl: beta * dl + (1.0 - beta) * gl,
                         d_prev, g)
    else:  # sgd
        d_honest = g

    # Omniscient attacks read the POST-update buffers: worker i's count is
    # incremented and its honest momentum written before little/empire
    # compute their weighted mean/std and z_max — matching the synchronous
    # group step (dist/steps.py), which attacks counts_new/D_new. (The
    # Byzantine row itself is masked out of the honest statistics, but the
    # weight masses entering little's z_max must track update counts.)
    S = state.S.at[i].set(s_new)
    D_upd = _set_row(state.D, i, d_honest)
    atk = attack_fn(D_upd, ~byz_mask, S, d_honest)
    d_sent = _tmap(lambda a, h: jnp.where(is_byz, a, h), atk, d_honest)

    D = _set_row(D_upd, i, d_sent)
    Xq = _set_row(state.Xq, i, query)

    # --- server update (lines 4-7) --------------------------------------
    S_agg = S if weighted is None else jnp.where(weighted, S, jnp.ones_like(S))
    d_hat = agg_fn(D, S_agg)
    # α_t = t is the AnyTime importance weight — μ²-SGD only (with the
    # constant-γ practical variant it folds into the learning rate).
    alpha = (t_next.astype(jnp.float32)
             if (opt.name == "mu2" and opt.gamma is None)
             else jnp.asarray(1.0, jnp.float32))
    w_new = _tmap(lambda wl, dl: wl - opt.lr * alpha * dl, state.w, d_hat)
    if opt.proj_radius is not None:
        # Π_K: project onto the ball of radius proj_radius around x_1
        # (compact K) — GLOBAL norm across all leaves
        diff = _tmap(jnp.subtract, w_new, anchor)
        sq = sum(jnp.sum(jnp.square(l))
                 for l in jax.tree_util.tree_leaves(diff))
        scale = jnp.minimum(1.0, opt.proj_radius
                            / jnp.maximum(jnp.sqrt(sq), 1e-30))
        w_new = _tmap(lambda a, dl: a + scale * dl, anchor, diff)
    if opt.name == "mu2":
        gcoef = anytime_coeff(t_next + 1, opt.gamma)
        x_new = _tmap(lambda xl, wl: xl + gcoef * (wl - xl), state.x, w_new)
    else:
        x_new = w_new

    new_state = EngineState(
        w=w_new, x=x_new, D=D, S=S, Xq=Xq,
        t=t_next, t_byz=state.t_byz + is_byz.astype(jnp.int32), key=key,
    )
    metrics = {"loss": loss, "worker": i, "is_byz": is_byz,
               "lambda_emp": new_state.t_byz / jnp.maximum(t_next, 1)}
    if collect_metrics:
        from repro.obs.metrics import MASS_EDGES, histogram
        mass = S_agg / jnp.maximum(jnp.sum(S_agg), 1e-30)
        # anchor: the weighted (non-robust) mean the rule is defending — the
        # gap to d_hat is the correction the robust rule applied this step
        mean = _tmap(lambda l: jnp.tensordot(mass, l, axes=1), D)
        sq = sum(jnp.sum(jnp.square(dl - ml))
                 for dl, ml in zip(jax.tree_util.tree_leaves(d_hat),
                                   jax.tree_util.tree_leaves(mean)))
        metrics.update({
            "engine.weight_mass": mass,
            "engine.weight_mass_hist": histogram(mass, MASS_EDGES),
            "engine.byz_mass": jnp.sum(jnp.where(byz_mask, mass, 0.0)),
            "engine.anchor_dist": jnp.sqrt(sq),
        })
    return new_state, metrics


def make_step_fn(cfg: EngineConfig, loss_fn: Callable, *,
                 agg_fn: Optional[Callable] = None,
                 attack_fn: Optional[Callable] = None,
                 per_worker_batch: bool = False,
                 collect_metrics: bool = False) -> Callable:
    """Build ``step(state, batch, probs, byz_mask, weighted=None)`` — the
    pure Alg. 2 iteration ``repro.fleet`` vmaps over scenario batches.

    Scenarios sharing a compile signature (same cfg statics / spec / loss)
    share ONE jit of the returned callable; proj_radius is unsupported here
    (the anchor is per-run state — use the sequential engine).
    ``collect_metrics`` (static) threads the ``engine.*`` telemetry outputs
    through — see :func:`engine_step`."""
    if cfg.opt.proj_radius is not None:
        raise ValueError("make_step_fn: proj_radius requires the per-run "
                         "anchor — drive engine_step directly or use "
                         "AsyncByzantineEngine")
    cfg.validate()
    if agg_fn is None:
        from repro.agg import resolve
        agg_fn = resolve(cfg.agg, lam=cfg.lam, backend=cfg.agg_backend)
    if attack_fn is None:
        attack_fn = partial(byzantine_vector, cfg.attack)
    value_grad_fn = jax.value_and_grad(loss_fn)
    grad_fn = jax.grad(loss_fn)

    def step(state: EngineState, batch: Any, probs: Array, byz_mask: Array,
             weighted: Optional[Array] = None):
        return engine_step(cfg, value_grad_fn, grad_fn, agg_fn, attack_fn,
                           state, batch, probs, byz_mask, weighted=weighted,
                           per_worker_batch=per_worker_batch,
                           collect_metrics=collect_metrics)

    return step


class AsyncByzantineEngine:
    """Runs Alg. 2 for an arbitrary model given a pytree loss function.

    Args:
      cfg: engine configuration.
      loss_fn: ``loss_fn(params, batch) -> scalar`` — differentiable in the
        params pytree. A flat ``(d,)`` vector is a valid (single-leaf) pytree.
      d_dim: legacy hint for the flat-vector shim (unused — shapes come from
        the params handed to ``init``); kept so existing callers don't break.
    """

    def __init__(self, cfg: EngineConfig, loss_fn: Callable[[Pytree, Any], Array],
                 d_dim: Optional[int] = None,
                 attack_fn: Optional[Callable] = None,
                 collect_metrics: bool = False):
        self.cfg = cfg.validate()
        self.loss_fn = loss_fn
        self.d_dim = d_dim
        # STATIC obs flag, read at trace time: False (default) keeps the
        # step's uninstrumented HLO, True adds the engine.* metric outputs.
        self.collect_metrics = collect_metrics
        self.grad_fn = jax.grad(loss_fn)
        self.value_grad_fn = jax.value_and_grad(loss_fn)
        self.agg_fn = self._make_agg_fn(cfg)
        # Attack override seam: repro.fleet.adaptive installs attackers tuned
        # against self.agg_fn; the default is the static Appendix D suite.
        self.attack_fn = attack_fn or partial(byzantine_vector, cfg.attack)
        self.probs = jnp.asarray(arrival_probs(cfg))
        self.byz_mask = jnp.asarray(byz_mask_array(cfg.m, cfg.byz))
        self._step = jax.jit(self._step_impl, donate_argnums=(0,))

    @staticmethod
    def _make_agg_fn(cfg: EngineConfig):
        """ONE resolve path (repro.agg): the returned callable dispatches per
        layout, so the same engine serves flat-vector and pytree models."""
        from repro.agg import resolve
        return resolve(cfg.agg, lam=cfg.lam,
                       backend=getattr(cfg, "agg_backend", "auto"))

    # -- initialization ----------------------------------------------------
    def init(self, params: Pytree, init_batches: Any) -> EngineState:
        """Alg. 2 line 2: every worker computes d_1 at x_1 on its own sample.

        ``params`` is the model pytree (or a flat ``(d,)`` vector);
        ``init_batches`` has leading axis m (one minibatch per worker).
        """
        x1 = _tmap(jnp.asarray, params)
        # independent buffers: the step donates the state, so no aliasing allowed
        self._anchor = _tmap(lambda l: l.copy(), x1)  # compact-K projection center
        return engine_init(self.cfg, self.grad_fn, x1, init_batches,
                           self.byz_mask)

    # -- one server iteration ----------------------------------------------
    def _step_impl(self, state: EngineState, batch: Any) -> tuple[EngineState, dict]:
        # self.agg_fn / self.attack_fn are read at TRACE time, so callers may
        # swap them (the non-weighted ablation, adaptive attackers) and re-jit.
        anchor = (self._anchor if self.cfg.opt.proj_radius is not None
                  else None)
        return engine_step(self.cfg, self.value_grad_fn, self.grad_fn,
                           self.agg_fn, self.attack_fn, state, batch,
                           self.probs, self.byz_mask, anchor=anchor,
                           collect_metrics=self.collect_metrics)

    def step(self, state: EngineState, batch: Any) -> tuple[EngineState, dict]:
        return self._step(state, batch)

    def run(self, state: EngineState, batches, steps: int,
            eval_fn: Optional[Callable[[Pytree], dict]] = None,
            eval_every: int = 0, obs=None) -> tuple[EngineState, list]:
        """Drive the loop; ``batches`` is an iterator of per-step minibatches.

        ``obs`` (a :class:`repro.obs.RunObs`) streams the per-step telemetry:
        loss / empirical-lambda every step, the arriving worker's staleness
        (server iterations since its previous arrival — derived HOST-side
        from the step's worker stream, so no extra state field changes the
        donated pytree), and, when the engine was built with
        ``collect_metrics=True``, the device-collected ``engine.*`` tree."""
        history = []
        last_arrival: dict[int, int] = {}
        for k in range(steps):
            state, metrics = self.step(state, next(batches))
            if obs is not None:
                step_no = k + 1
                worker = int(metrics["worker"])
                obs.metric("engine.loss", metrics["loss"], step=step_no,
                           worker=worker)
                obs.metric("engine.lambda_emp", metrics["lambda_emp"],
                           step=step_no)
                obs.metric("engine.staleness",
                           step_no - last_arrival.get(worker, step_no),
                           step=step_no, worker=worker)
                last_arrival[worker] = step_no
                obs.metric_tree({n: v for n, v in metrics.items()
                                 if n.startswith("engine.")}, step=step_no)
            if eval_every and (k + 1) % eval_every == 0:
                rec = {"step": k + 1, "loss": float(metrics["loss"]),
                       "lambda_emp": float(metrics["lambda_emp"])}
                if eval_fn is not None:
                    rec.update(eval_fn(state.x))
                history.append(rec)
        return state, history
