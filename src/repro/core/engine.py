"""Asynchronous Byzantine parameter-server simulator — paper Algorithm 2.

Faithful event-driven reproduction: one worker arrives per server iteration
(sampled from an arrival distribution or round-robin), delivers its corrected
momentum ``d_t^{(i)}``, the server robust-aggregates ALL workers' latest
buffers weighted by their update counts ``s_t^{(i)}``, applies the AnyTime
update, and hands the worker the fresh query point.

PYTREE-NATIVE state: the model parameters are an arbitrary pytree, and the
per-worker buffers are STACKED pytrees whose leaves carry a leading worker
axis ``(m, ...)`` — the same layout as ``dist.steps``, so both paths
aggregate through the one layout-polymorphic ``repro.agg`` API (and the
fused Pallas kernels apply to both). A flat ``(d,)`` parameter vector is
simply the single-leaf case — the thin shim the paper-CNN experiments use:
every state field then stays a plain array, exactly the legacy layout.

State layout (leaves shown for a flat (d,)-vector model):
    w, x            (d,)    iterate / AnyTime average (query point)
    D               (m, d)  latest momentum from each worker (Alg. 2 line 5)
    S               (m,)    update counts s_t^{(i)}  (the aggregation weights)
    Xq              (m, d)  last query point handed to each worker (for g̃)
    t, t_byz        ()      iteration counters (λ accounting, Eq. 6)

The whole server iteration is a single jitted step. Byzantine behaviors follow
Appendix D: label flipping poisons the worker's labels before the gradient;
sign flipping negates the transmission; little/empire are omniscient and read
the honest workers' buffers with their weights.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .attacks import AttackConfig, byzantine_vector, flip_labels
from ..optim.mu2sgd import OptConfig, anytime_coeff

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map


def _row(tree: Pytree, i) -> Pytree:
    """Slice worker i's row out of a stacked tree."""
    return _tmap(lambda l: l[i], tree)


def _set_row(tree: Pytree, i, val: Pytree) -> Pytree:
    return _tmap(lambda l, v: l.at[i].set(v), tree, val)


class EngineConfig(NamedTuple):
    m: int                                  # number of workers
    byz: tuple                              # tuple of Byzantine worker ids
    attack: AttackConfig = AttackConfig()
    agg: str = "ctma:cwmed"                 # repro.agg spec: rule[:base][@backend]
    lam: float = 0.2                        # λ for the meta-aggregator / trimming
    opt: OptConfig = OptConfig(name="mu2", lr=0.01, gamma=0.1, beta=0.25)
    arrival: str = "proportional"           # proportional | squared | uniform | round_robin
    byz_start_step: int = 0                 # attacks activate after this iteration
    n_classes: int = 10
    seed: int = 0
    # Flat-matrix aggregation backend (repro.agg): the server aggregation is
    # O(m·d) over the full momentum buffer every iteration — far from free at
    # production d. A backend embedded in ``agg`` ("ctma:gm@pallas") wins.
    #   auto   — fused Pallas kernels on TPU, jnp oracle elsewhere
    #   pallas — force the fused kernel path (interpret mode off-TPU)
    #   jnp    — force the pure-jnp aggregators
    agg_backend: str = "auto"


class EngineState(NamedTuple):
    w: Pytree
    x: Pytree
    D: Pytree
    S: Array
    Xq: Pytree
    t: Array
    t_byz: Array
    key: Array


def arrival_probs(cfg: EngineConfig) -> np.ndarray:
    ids = np.arange(1, cfg.m + 1, dtype=np.float64)
    if cfg.arrival == "proportional":
        p = ids
    elif cfg.arrival == "squared":
        p = ids ** 2
    elif cfg.arrival in ("uniform", "round_robin"):
        p = np.ones_like(ids)
    else:
        raise KeyError(cfg.arrival)
    return (p / p.sum()).astype(np.float32)


def expected_lambda(cfg: EngineConfig) -> float:
    """Expected fraction of Byzantine updates under the arrival distribution."""
    p = arrival_probs(cfg)
    return float(sum(p[i] for i in cfg.byz))


class AsyncByzantineEngine:
    """Runs Alg. 2 for an arbitrary model given a pytree loss function.

    Args:
      cfg: engine configuration.
      loss_fn: ``loss_fn(params, batch) -> scalar`` — differentiable in the
        params pytree. A flat ``(d,)`` vector is a valid (single-leaf) pytree.
      d_dim: legacy hint for the flat-vector shim (unused — shapes come from
        the params handed to ``init``); kept so existing callers don't break.
    """

    def __init__(self, cfg: EngineConfig, loss_fn: Callable[[Pytree, Any], Array],
                 d_dim: Optional[int] = None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.d_dim = d_dim
        self.grad_fn = jax.grad(loss_fn)
        self.value_grad_fn = jax.value_and_grad(loss_fn)
        self.agg_fn = self._make_agg_fn(cfg)
        self.probs = jnp.asarray(arrival_probs(cfg))
        byz_mask = np.zeros((cfg.m,), bool)
        for i in cfg.byz:
            byz_mask[i] = True
        self.byz_mask = jnp.asarray(byz_mask)
        self._step = jax.jit(self._step_impl, donate_argnums=(0,))

    @staticmethod
    def _make_agg_fn(cfg: EngineConfig):
        """ONE resolve path (repro.agg): the returned callable dispatches per
        layout, so the same engine serves flat-vector and pytree models."""
        from repro.agg import resolve
        return resolve(cfg.agg, lam=cfg.lam,
                       backend=getattr(cfg, "agg_backend", "auto"))

    # -- initialization ----------------------------------------------------
    def init(self, params: Pytree, init_batches: Any) -> EngineState:
        """Alg. 2 line 2: every worker computes d_1 at x_1 on its own sample.

        ``params`` is the model pytree (or a flat ``(d,)`` vector);
        ``init_batches`` has leading axis m (one minibatch per worker).
        """
        cfg = self.cfg
        x1 = _tmap(jnp.asarray, params)
        # independent buffers: the step donates the state, so no aliasing allowed
        self._anchor = _tmap(lambda l: l.copy(), x1)  # compact-K projection center

        def one(i, batch):
            lk = "y" if "y" in batch else "labels"
            y = batch[lk]
            y = jnp.where(self.byz_mask[i] & (cfg.attack.name == "label_flip") & (cfg.byz_start_step <= 0),
                          flip_labels(y, cfg.n_classes), y)
            return self.grad_fn(x1, {**batch, lk: y})

        D = jax.vmap(one, in_axes=(0, 0))(jnp.arange(cfg.m), init_batches)
        if cfg.attack.name == "sign_flip" and cfg.byz_start_step <= 0:
            mask = self.byz_mask

            def flip(l):
                byz = mask.reshape((cfg.m,) + (1,) * (l.ndim - 1))
                return jnp.where(byz, -l, l)

            D = _tmap(flip, D)
        S = jnp.zeros((cfg.m,), jnp.float32)
        Xq = _tmap(lambda l: jnp.broadcast_to(l, (cfg.m,) + l.shape).copy(), x1)
        return EngineState(
            w=_tmap(lambda l: l.copy(), x1), x=_tmap(lambda l: l.copy(), x1),
            D=D, S=S, Xq=Xq,
            t=jnp.zeros((), jnp.int32), t_byz=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(cfg.seed),
        )

    # -- one server iteration ----------------------------------------------
    def _step_impl(self, state: EngineState, batch: Any) -> tuple[EngineState, dict]:
        cfg = self.cfg
        opt = cfg.opt
        key, k_arrival = jax.random.split(state.key)

        t_next = state.t + 1
        if cfg.arrival == "round_robin":
            i = (state.t % cfg.m).astype(jnp.int32)
        else:
            i = jax.random.categorical(k_arrival, jnp.log(self.probs))

        is_byz = self.byz_mask[i] & (t_next > cfg.byz_start_step)

        # --- worker computation (lines 8-10) -------------------------------
        label_key = "y" if "y" in batch else "labels"
        y = batch[label_key]
        y_used = jnp.where(is_byz & (cfg.attack.name == "label_flip"),
                           flip_labels(y, cfg.n_classes), y)
        batch_used = {**batch, label_key: y_used}

        query = state.x if opt.name == "mu2" else state.w
        loss, g = self.value_grad_fn(query, batch_used)

        s_new = state.S[i] + 1.0
        d_prev = _row(state.D, i)
        if opt.name == "mu2":
            g_tilde = self.grad_fn(_row(state.Xq, i), batch_used)  # same sample z_t
            beta = (jnp.asarray(opt.beta, jnp.float32) if opt.beta is not None
                    else 1.0 / jnp.maximum(s_new, 1.0))
            d_honest = _tmap(
                lambda gl, dl, gtl: jnp.where(s_new <= 1.0, gl,
                                              gl + (1.0 - beta) * (dl - gtl)),
                g, d_prev, g_tilde)
        elif opt.name == "momentum":
            beta = 0.9 if opt.beta is None else opt.beta
            d_honest = _tmap(lambda dl, gl: beta * dl + (1.0 - beta) * gl,
                             d_prev, g)
        else:  # sgd
            d_honest = g

        # Omniscient attacks read the POST-update buffers: worker i's count is
        # incremented and its honest momentum written before little/empire
        # compute their weighted mean/std and z_max — matching the synchronous
        # group step (dist/steps.py), which attacks counts_new/D_new. (The
        # Byzantine row itself is masked out of the honest statistics, but the
        # weight masses entering little's z_max must track update counts.)
        S = state.S.at[i].set(s_new)
        D_upd = _set_row(state.D, i, d_honest)
        atk = byzantine_vector(cfg.attack, D_upd, ~self.byz_mask, S, d_honest)
        d_sent = _tmap(lambda a, h: jnp.where(is_byz, a, h), atk, d_honest)

        D = _set_row(D_upd, i, d_sent)
        Xq = _set_row(state.Xq, i, query)

        # --- server update (lines 4-7) --------------------------------------
        d_hat = self.agg_fn(D, S)
        # α_t = t is the AnyTime importance weight — μ²-SGD only (with the
        # constant-γ practical variant it folds into the learning rate).
        alpha = (t_next.astype(jnp.float32)
                 if (opt.name == "mu2" and opt.gamma is None)
                 else jnp.asarray(1.0, jnp.float32))
        w_new = _tmap(lambda wl, dl: wl - opt.lr * alpha * dl, state.w, d_hat)
        if opt.proj_radius is not None:
            # Π_K: project onto the ball of radius proj_radius around x_1
            # (compact K) — GLOBAL norm across all leaves
            diff = _tmap(jnp.subtract, w_new, self._anchor)
            sq = sum(jnp.sum(jnp.square(l))
                     for l in jax.tree_util.tree_leaves(diff))
            scale = jnp.minimum(1.0, opt.proj_radius
                                / jnp.maximum(jnp.sqrt(sq), 1e-30))
            w_new = _tmap(lambda a, dl: a + scale * dl, self._anchor, diff)
        if opt.name == "mu2":
            gcoef = anytime_coeff(t_next + 1, opt.gamma)
            x_new = _tmap(lambda xl, wl: xl + gcoef * (wl - xl), state.x, w_new)
        else:
            x_new = w_new

        new_state = EngineState(
            w=w_new, x=x_new, D=D, S=S, Xq=Xq,
            t=t_next, t_byz=state.t_byz + is_byz.astype(jnp.int32), key=key,
        )
        metrics = {"loss": loss, "worker": i, "is_byz": is_byz,
                   "lambda_emp": new_state.t_byz / jnp.maximum(t_next, 1)}
        return new_state, metrics

    def step(self, state: EngineState, batch: Any) -> tuple[EngineState, dict]:
        return self._step(state, batch)

    def run(self, state: EngineState, batches, steps: int,
            eval_fn: Optional[Callable[[Pytree], dict]] = None,
            eval_every: int = 0) -> tuple[EngineState, list]:
        """Drive the loop; ``batches`` is an iterator of per-step minibatches."""
        history = []
        for k in range(steps):
            state, metrics = self.step(state, next(batches))
            if eval_every and (k + 1) % eval_every == 0:
                rec = {"step": k + 1, "loss": float(metrics["loss"]),
                       "lambda_emp": float(metrics["lambda_emp"])}
                if eval_fn is not None:
                    rec.update(eval_fn(state.x))
                history.append(rec)
        return state, history
