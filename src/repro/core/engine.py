"""Asynchronous Byzantine parameter-server simulator — paper Algorithm 2.

Faithful event-driven reproduction: one worker arrives per server iteration
(sampled from an arrival distribution or round-robin), delivers its corrected
momentum ``d_t^{(i)}``, the server robust-aggregates ALL workers' latest
buffers weighted by their update counts ``s_t^{(i)}``, applies the AnyTime
update, and hands the worker the fresh query point.

State layout (flat vectors, d = number of parameters):
    w, x            (d,)    iterate / AnyTime average (query point)
    D               (m, d)  latest momentum from each worker (Alg. 2 line 5)
    S               (m,)    update counts s_t^{(i)}  (the aggregation weights)
    Xq              (m, d)  last query point handed to each worker (for g̃)
    t, t_byz        ()      iteration counters (λ accounting, Eq. 6)

The whole server iteration is a single jitted step. Byzantine behaviors follow
Appendix D: label flipping poisons the worker's labels before the gradient;
sign flipping negates the transmission; little/empire are omniscient and read
the honest workers' buffers with their weights.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .aggregators import make_aggregator
from .attacks import AttackConfig, byzantine_vector, flip_labels
from ..optim.mu2sgd import OptConfig, anytime_coeff

Array = jnp.ndarray
Pytree = Any


class EngineConfig(NamedTuple):
    m: int                                  # number of workers
    byz: tuple                              # tuple of Byzantine worker ids
    attack: AttackConfig = AttackConfig()
    agg: str = "ctma:cwmed"                 # aggregator spec
    lam: float = 0.2                        # λ for the meta-aggregator / trimming
    opt: OptConfig = OptConfig(name="mu2", lr=0.01, gamma=0.1, beta=0.25)
    arrival: str = "proportional"           # proportional | squared | uniform | round_robin
    byz_start_step: int = 0                 # attacks activate after this iteration
    n_classes: int = 10
    seed: int = 0
    # Aggregation backend. The server aggregation is O(m·d) over the full
    # momentum buffer every iteration — far from free at production d.
    #   auto   — fused Pallas kernels on TPU, jnp oracle elsewhere
    #   pallas — force the fused kernel path (interpret mode off-TPU)
    #   jnp    — force the pure-jnp aggregators
    agg_backend: str = "auto"


class EngineState(NamedTuple):
    w: Array
    x: Array
    D: Array
    S: Array
    Xq: Array
    t: Array
    t_byz: Array
    key: Array


def arrival_probs(cfg: EngineConfig) -> np.ndarray:
    ids = np.arange(1, cfg.m + 1, dtype=np.float64)
    if cfg.arrival == "proportional":
        p = ids
    elif cfg.arrival == "squared":
        p = ids ** 2
    elif cfg.arrival in ("uniform", "round_robin"):
        p = np.ones_like(ids)
    else:
        raise KeyError(cfg.arrival)
    return (p / p.sum()).astype(np.float32)


def expected_lambda(cfg: EngineConfig) -> float:
    """Expected fraction of Byzantine updates under the arrival distribution."""
    p = arrival_probs(cfg)
    return float(sum(p[i] for i in cfg.byz))


class AsyncByzantineEngine:
    """Runs Alg. 2 for an arbitrary model given a flat loss/grad function.

    Args:
      cfg: engine configuration.
      loss_fn: ``loss_fn(flat_params, batch) -> scalar`` — differentiable.
      d_dim: number of parameters (flattened).
    """

    def __init__(self, cfg: EngineConfig, loss_fn: Callable[[Array, Any], Array], d_dim: int):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.d_dim = d_dim
        self.grad_fn = jax.grad(loss_fn)
        self.value_grad_fn = jax.value_and_grad(loss_fn)
        self.agg_fn = self._make_agg_fn(cfg)
        self.probs = jnp.asarray(arrival_probs(cfg))
        byz_mask = np.zeros((cfg.m,), bool)
        for i in cfg.byz:
            byz_mask[i] = True
        self.byz_mask = jnp.asarray(byz_mask)
        self._step = jax.jit(self._step_impl, donate_argnums=(0,))

    @staticmethod
    def _make_agg_fn(cfg: EngineConfig):
        backend = getattr(cfg, "agg_backend", "auto")
        if backend not in ("auto", "pallas", "jnp"):
            raise KeyError(f"unknown agg_backend {backend!r}; "
                           "choose from auto | pallas | jnp")
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        if backend == "pallas":
            from ..kernels.ops import make_kernel_aggregator
            return make_kernel_aggregator(
                cfg.agg, lam=cfg.lam, interpret=jax.default_backend() != "tpu")
        return make_aggregator(cfg.agg, lam=cfg.lam)

    # -- initialization ----------------------------------------------------
    def init(self, params_flat: Array, init_batches: Any) -> EngineState:
        """Alg. 2 line 2: every worker computes d_1 at x_1 on its own sample.

        ``init_batches`` has leading axis m (one minibatch per worker).
        """
        cfg = self.cfg
        x1 = jnp.asarray(params_flat)
        # independent buffers: the step donates the state, so no aliasing allowed
        self._anchor = x1.copy()  # projection center for the compact-K assumption

        def one(i, batch):
            lk = "y" if "y" in batch else "labels"
            y = batch[lk]
            y = jnp.where(self.byz_mask[i] & (cfg.attack.name == "label_flip") & (cfg.byz_start_step <= 0),
                          flip_labels(y, cfg.n_classes), y)
            return self.grad_fn(x1, {**batch, lk: y})

        D = jax.vmap(one, in_axes=(0, 0))(jnp.arange(cfg.m), init_batches)
        if cfg.attack.name == "sign_flip" and cfg.byz_start_step <= 0:
            D = jnp.where(self.byz_mask[:, None], -D, D)
        S = jnp.zeros((cfg.m,), jnp.float32)
        Xq = jnp.broadcast_to(x1, (cfg.m, self.d_dim)).copy()
        return EngineState(
            w=x1.copy(), x=x1.copy(), D=D, S=S, Xq=Xq,
            t=jnp.zeros((), jnp.int32), t_byz=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(cfg.seed),
        )

    # -- one server iteration ----------------------------------------------
    def _step_impl(self, state: EngineState, batch: Any) -> tuple[EngineState, dict]:
        cfg = self.cfg
        opt = cfg.opt
        key, k_arrival = jax.random.split(state.key)

        t_next = state.t + 1
        if cfg.arrival == "round_robin":
            i = (state.t % cfg.m).astype(jnp.int32)
        else:
            i = jax.random.categorical(k_arrival, jnp.log(self.probs))

        is_byz = self.byz_mask[i] & (t_next > cfg.byz_start_step)

        # --- worker computation (lines 8-10) -------------------------------
        label_key = "y" if "y" in batch else "labels"
        y = batch[label_key]
        y_used = jnp.where(is_byz & (cfg.attack.name == "label_flip"),
                           flip_labels(y, cfg.n_classes), y)
        batch_used = {**batch, label_key: y_used}

        query = state.x if opt.name == "mu2" else state.w
        loss, g = self.value_grad_fn(query, batch_used)

        s_new = state.S[i] + 1.0
        if opt.name == "mu2":
            g_tilde = self.grad_fn(state.Xq[i], batch_used)  # same sample z_t
            beta = (jnp.asarray(opt.beta, jnp.float32) if opt.beta is not None
                    else 1.0 / jnp.maximum(s_new, 1.0))
            d_honest = jnp.where(s_new <= 1.0, g, g + (1.0 - beta) * (state.D[i] - g_tilde))
        elif opt.name == "momentum":
            beta = 0.9 if opt.beta is None else opt.beta
            d_honest = beta * state.D[i] + (1.0 - beta) * g
        else:  # sgd
            d_honest = g

        atk = byzantine_vector(cfg.attack, state.D, ~self.byz_mask, state.S, d_honest)
        d_sent = jnp.where(is_byz, atk, d_honest)

        D = state.D.at[i].set(d_sent)
        S = state.S.at[i].set(s_new)
        Xq = state.Xq.at[i].set(query)

        # --- server update (lines 4-7) --------------------------------------
        d_hat = self.agg_fn(D, S)
        # α_t = t is the AnyTime importance weight — μ²-SGD only (with the
        # constant-γ practical variant it folds into the learning rate).
        alpha = (t_next.astype(jnp.float32)
                 if (opt.name == "mu2" and opt.gamma is None)
                 else jnp.asarray(1.0, jnp.float32))
        w_new = state.w - opt.lr * alpha * d_hat
        if opt.proj_radius is not None:
            # Π_K: project onto the ball of radius proj_radius around x_1 (compact K)
            diff = w_new - self._anchor
            norm = jnp.linalg.norm(diff)
            w_new = self._anchor + diff * jnp.minimum(1.0, opt.proj_radius / jnp.maximum(norm, 1e-30))
        if opt.name == "mu2":
            gcoef = anytime_coeff(t_next + 1, opt.gamma)
            x_new = state.x + gcoef * (w_new - state.x)
        else:
            x_new = w_new

        new_state = EngineState(
            w=w_new, x=x_new, D=D, S=S, Xq=Xq,
            t=t_next, t_byz=state.t_byz + is_byz.astype(jnp.int32), key=key,
        )
        metrics = {"loss": loss, "worker": i, "is_byz": is_byz,
                   "lambda_emp": new_state.t_byz / jnp.maximum(t_next, 1)}
        return new_state, metrics

    def step(self, state: EngineState, batch: Any) -> tuple[EngineState, dict]:
        return self._step(state, batch)

    def run(self, state: EngineState, batches, steps: int,
            eval_fn: Optional[Callable[[Array], dict]] = None,
            eval_every: int = 0) -> tuple[EngineState, list]:
        """Drive the loop; ``batches`` is an iterator of per-step minibatches."""
        history = []
        for k in range(steps):
            state, metrics = self.step(state, next(batches))
            if eval_every and (k + 1) % eval_every == 0:
                rec = {"step": k + 1, "loss": float(metrics["loss"]),
                       "lambda_emp": float(metrics["lambda_emp"])}
                if eval_fn is not None:
                    rec.update(eval_fn(state.x))
                history.append(rec)
        return state, history
