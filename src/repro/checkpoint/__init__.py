"""Dependency-free numpy pytree checkpointing (save / restore / latest)."""
from .np_checkpoint import latest_step, restore_pytree, save_pytree  # noqa: F401
