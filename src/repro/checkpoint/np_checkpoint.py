"""Minimal dependency-free checkpointing: pytree <-> npz keyed by tree paths.

Values are fully materialized on host (suitable for single-process CPU runs
and tests; a production deployment would swap in tensorstore-backed shards —
the interface is the same).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "##"


def _flatten_with_paths(tree: Pytree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree: Pytree, ckpt_dir: str | Path, step: int) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(tree)
    path = ckpt_dir / f"step_{step:09d}.npz"
    np.savez(path, **flat)
    (ckpt_dir / "latest.json").write_text(json.dumps({"step": step}))
    return path


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    meta = Path(ckpt_dir) / "latest.json"
    if not meta.exists():
        return None
    return int(json.loads(meta.read_text())["step"])


def restore_pytree(target: Pytree, ckpt_dir: str | Path, step: Optional[int] = None) -> Pytree:
    """Restore into the structure of ``target`` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(Path(ckpt_dir) / f"step_{step:09d}.npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
