"""Byzantine-tolerant replicated decode — weighted robust logit voting.

``ReplicatedServeEngine`` unites the two halves of the repo: it runs R decode
replicas of the serving engine (stacked params + per-replica KV caches, one
vmapped jitted step decodes all of them) and resolves every token's logits
through the unified ``repro.agg`` registry, weighted by per-replica
checkpoint STALENESS exactly as the paper weights asynchronous updates by
delay (``agg.staleness_weights``: a replica at version ``latest - lag``
carries mass ``latest - lag``).

Pipeline, per decoded token::

    params_stack (R, ...) ──┐
    cache_stack  (R, ...) ──┴─► vmapped decode ─► logits (R, S, V)
                                     │
                       corrupt_logits (core.attacks): Byzantine replicas
                       transform their reported rows (corrupt / sign_flip /
                       little / empire); dead / hanging replicas miss the
                       vote (mass 0); stale replicas serve old checkpoints
                                     │
                   ω-vote: agg.resolve_logits(vote)(logits, weights)
                   weights = staleness masses × availability × quarantine
                                     │
                   Zeno++-style pre-vote scores vs the robust anchor
                   (host-side quarantine: strikes → evict → backoff → readmit)
                                     │
                            sample_next ─► ONE voted token, fed back to
                            every replica (keeps all R caches coherent)

Graceful degradation: a replica whose score stays under
``zeno_threshold`` for ``quarantine_after`` consecutive decode steps is
evicted from the vote (mass 0) for ``readmit_after`` steps, doubling per
repeat eviction (``backoff_factor``); it keeps decoding the voted stream
while quarantined so its KV cache is valid on re-admission. Per-replica
health (votes, divergent tokens, evictions, mean score) lands in
:class:`ReplicatedServeReport`.

Correctness anchor (pinned in tests/test_replicated_serve.py): with all
replicas honest and fresh, greedy streams are TOKEN-IDENTICAL to the
single-replica ``ServeEngine`` — the vmapped decode is bitwise-equal per
replica and every robust rule returns the common row of an identical stack.
With f < R/2 Byzantine vote mass the weighted median's crossing stays inside
the honest mass, so the voted greedy stream still matches the honest one.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg.logits import staleness_weights
from repro.core.attacks import LOGIT_ATTACKS, LogitAttackConfig
from repro.dist.steps import (make_replicated_decode_step,
                              make_replicated_prefill_step,
                              make_replicated_unified_step, sample_next,
                              vote_logits_fn)
from repro.models.config import ModelConfig
from repro.serve.cache import insert_prefill, insert_prefill_paged
from repro.serve.engine import ServeConfig, ServeEngine, ServeReport

Pytree = Any

_tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class ReplicatedConfig:
    """Replica fleet + fault plan + vote / quarantine policy."""
    n_replicas: int = 3
    vote: str = "cwmed"            # repro.agg spec for the per-token vote
    lam: float = 0.25              # λ for meta-rules (ctma:..., zeno)
    # fault injection
    attack: LogitAttackConfig = LogitAttackConfig()
    byz: Tuple[int, ...] = ()      # replicas transmitting corrupted logits
    lags: Tuple[int, ...] = ()     # per-replica checkpoint staleness; () = fresh
    latest_version: Optional[float] = None  # staleness_weights reference
    dead: Tuple[int, ...] = ()     # replicas that stop responding...
    dead_after: int = 0            # ...from this decode step on
    hang: Tuple[int, ...] = ()     # replicas with intermittent stalls: they
    hang_period: int = 4           # miss every hang_period-th vote
    # graceful degradation (Zeno++-style pre-vote gate)
    zeno_rho: float = 1e-3
    zeno_threshold: float = 0.5    # score below this = divergent token
    quarantine_after: int = 3      # consecutive divergent tokens -> evict
    readmit_after: int = 32        # base backoff (decode steps)
    backoff_factor: float = 2.0    # backoff multiplier per repeat eviction
    attack_seed: int = 0           # PRNG seed for the 'corrupt' noise draws

    def role(self, r: int) -> str:
        if r in self.byz:
            return "byzantine"
        if r in self.dead:
            return "dead"
        if r in self.hang:
            return "hanging"
        return "honest"

    def validate(self) -> None:
        R = self.n_replicas
        if R < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.attack.name not in LOGIT_ATTACKS:
            raise ValueError(f"unknown logit attack {self.attack.name!r}; "
                             f"choose from {LOGIT_ATTACKS}")
        for label, ids in (("byz", self.byz), ("dead", self.dead),
                           ("hang", self.hang)):
            bad = [i for i in ids if not 0 <= i < R]
            if bad:
                raise ValueError(f"{label} replica ids {bad} out of range "
                                 f"for n_replicas={R}")
        if self.lags and len(self.lags) != R:
            raise ValueError(f"lags must have one entry per replica "
                             f"({len(self.lags)} != {R})")
        if self.hang_period < 2:
            raise ValueError("hang_period must be >= 2")


@dataclasses.dataclass
class ReplicaHealth:
    """Host-side health record for one replica (rides in the report)."""
    replica: int
    role: str
    lag: float = 0.0
    weight: float = 0.0            # staleness-derived base vote mass
    tokens_voted: int = 0          # decode votes it held mass in
    tokens_missed: int = 0         # votes missed (dead / hanging)
    divergent_tokens: int = 0      # votes scored under the zeno threshold
    strikes: int = 0               # current consecutive divergent tokens
    quarantined: bool = False
    quarantined_tokens: int = 0
    evictions: int = 0
    backoff_remaining: int = 0
    first_eviction_step: Optional[int] = None
    score_sum: float = 0.0
    score_n: int = 0

    @property
    def mean_score(self) -> float:
        return self.score_sum / self.score_n if self.score_n else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("score_sum"), d.pop("score_n"), d.pop("strikes")
        d["mean_score"] = round(self.mean_score, 4)
        return d


@dataclasses.dataclass
class ReplicatedServeReport(ServeReport):
    n_replicas: int = 0
    vote: str = ""
    attack: str = "none"
    replicas: List[dict] = dataclasses.field(default_factory=list)
    quarantine_events: List[dict] = dataclasses.field(default_factory=list)
    first_quarantine_step: Optional[int] = None  # decode steps to first evict


def stale_params_stack(params: Pytree, lags: Sequence[int], key,
                       drift: float = 1e-3) -> Pytree:
    """Stacked params (leaves (R, ...)) simulating a checkpoint shelf.

    Checkpoint version ``latest - L`` is the fresh ``params`` minus a shared
    Gaussian random walk of L steps of per-leaf scale ``drift`` — the SAME
    walk for every replica, so two replicas at the same lag serve the
    identical checkpoint (the heterogeneous-but-honest regime of Fixing by
    Mixing: honest replicas legitimately disagree, yet agree within a lag
    class)."""
    lags = [int(l) for l in lags]
    leaves, treedef = jax.tree_util.tree_flatten(params)
    deltas = [np.zeros(l.shape, np.float32) for l in leaves]
    shelf = {0: [np.zeros(l.shape, np.float32) for l in leaves]}
    for step in range(1, max(lags) + 1 if lags else 1):
        ks = jax.random.split(jax.random.fold_in(key, step), len(leaves))
        deltas = [d + drift * np.asarray(jax.random.normal(k, l.shape))
                  for d, k, l in zip(deltas, ks, leaves)]
        shelf[step] = [d.copy() for d in deltas]
    rows = []
    for lag in lags:
        rows.append(jax.tree_util.tree_unflatten(
            treedef, [(np.asarray(l, np.float32) - d).astype(l.dtype)
                      for l, d in zip(leaves, shelf[lag])]))
    return _tmap(lambda *ls: jnp.stack(ls), *rows)


def _stack_params(params: Union[Pytree, Sequence[Pytree]], R: int) -> Pytree:
    """Stack a list of R replica checkpoints, or broadcast a single one."""
    if isinstance(params, (list, tuple)):
        if len(params) != R:
            raise ValueError(f"got {len(params)} replica params for "
                             f"n_replicas={R}")
        return _tmap(lambda *ls: jnp.stack(ls), *params)
    return _tmap(lambda l: jnp.broadcast_to(l[None], (R,) + l.shape).copy(),
                 params)


class ReplicatedServeEngine(ServeEngine):
    """R-replica serving engine with per-token weighted robust logit voting.

    ``params`` may be a single pytree (broadcast to R fresh replicas), a list
    of R per-replica checkpoints, or — with ``rcfg.lags`` set — a single
    fresh pytree that :func:`stale_params_stack` turns into a simulated
    checkpoint shelf. Inherits admission, scheduling, paging and metrics
    from :class:`ServeEngine`; only the jitted steps (vmapped over the
    replica axis) and the vote/health layer differ."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 rcfg: ReplicatedConfig = ReplicatedConfig(),
                 engine: str = "continuous", mesh=None, obs=None):
        if mesh is not None:
            raise NotImplementedError("replicated serving + mesh: the replica "
                                      "axis is not wired into the shardings")
        rcfg.validate()
        self.rcfg = rcfg
        R = rcfg.n_replicas
        # STATIC device-metrics flag, fixed before the decode step is built:
        # True compiles the serve.vote.* collecting step variant (one compile
        # either way), False keeps the uninstrumented HLO
        self._collect = obs is not None and getattr(obs, "device_metrics",
                                                    False)
        if isinstance(params, (list, tuple)):
            base_params = params[0]
            params_stack = _stack_params(params, R)
        elif rcfg.lags and any(rcfg.lags):
            base_params = params
            params_stack = stale_params_stack(
                params, rcfg.lags, jax.random.PRNGKey(rcfg.attack_seed))
        else:
            base_params = params
            params_stack = _stack_params(params, R)

        super().__init__(cfg, base_params, scfg, engine=engine, obs=obs)

        # replicated report + staleness-derived base vote masses
        self.report = ReplicatedServeReport(
            engine=engine, paged=self.paged, n_replicas=R, vote=rcfg.vote,
            attack=rcfg.attack.name, chunked=self.chunked,
            chunk_size=self.chunk_size)
        if self.paged:
            self.report.page_size = scfg.page_size
            self.report.n_pages = self.pager.n_pages
        lags = rcfg.lags or tuple(0 for _ in range(R))
        self._base_w = np.asarray(
            staleness_weights(lags, rcfg.latest_version), np.float32)
        self.health = [
            ReplicaHealth(replica=r, role=rcfg.role(r), lag=float(lags[r]),
                          weight=float(self._base_w[r])) for r in range(R)]

        # swap the jitted steps for their replicated (vmapped) versions
        self.params = params_stack
        self.cache = _tmap(
            lambda l: jnp.zeros((R,) + l.shape, l.dtype), self.cache)
        if self.chunked:
            self._unified_jit = jax.jit(
                make_replicated_unified_step(
                    cfg, R, rcfg.attack, byz=rcfg.byz, vote=rcfg.vote,
                    lam=rcfg.lam, zeno_rho=rcfg.zeno_rho,
                    temperature=scfg.temperature, top_k=scfg.top_k,
                    paged=self.paged, collect_metrics=self._collect),
                donate_argnums=(1,))
            self._unified = self._voted_unified
        else:
            self._prefill = jax.jit(
                make_replicated_prefill_step(cfg, scfg.max_len))
            if self.paged:
                ins = functools.partial(insert_prefill_paged, cfg,
                                        scfg.page_size)
                self._insert = jax.jit(
                    jax.vmap(ins, in_axes=(0, 0, None, None)),
                    donate_argnums=(0,))
            else:
                self._insert = jax.jit(jax.vmap(insert_prefill,
                                                in_axes=(0, 0, None)),
                                       donate_argnums=(0,))
            self._decode_jit = jax.jit(
                make_replicated_decode_step(
                    cfg, R, rcfg.attack, byz=rcfg.byz, vote=rcfg.vote,
                    lam=rcfg.lam, zeno_rho=rcfg.zeno_rho,
                    temperature=scfg.temperature, top_k=scfg.top_k,
                    paged=self.paged, collect_metrics=self._collect),
                donate_argnums=(1,))
            self._decode = self._voted_decode

            vote_first = vote_logits_fn(rcfg.attack, rcfg.byz, R,
                                        vote=rcfg.vote, lam=rcfg.lam,
                                        zeno_rho=rcfg.zeno_rho)
            t, k = scfg.temperature, scfg.top_k

            def first_voted(logits, req_keys, weights, akey):
                voted, scores = vote_first(logits[:, :, 0, :], weights, akey)
                nxt = sample_next(voted, req_keys,
                                  jnp.zeros(req_keys.shape[0], jnp.int32),
                                  t, k)
                return nxt, scores

            self._first_jit = jax.jit(first_voted)
            self._first = self._voted_first

        self._attack_key = jax.random.PRNGKey(rcfg.attack_seed)
        self._attack_ctr = 0
        self._last_scores: Optional[np.ndarray] = None
        self._last_vm: Optional[dict] = None
        # warmup() drives _decode directly (no _decode_tick around it)
        self._w_now = self._base_w.copy()

    # ------------------------------------------------------------------
    # runtime vote mass: staleness × availability × quarantine
    # ------------------------------------------------------------------

    def _vote_weights(self) -> np.ndarray:
        t = self.report.decode_steps       # index of the upcoming decode step
        w = self._base_w.copy()
        for r in self.rcfg.dead:
            if t >= self.rcfg.dead_after:
                w[r] = 0.0
        for r in self.rcfg.hang:
            if t % self.rcfg.hang_period == self.rcfg.hang_period - 1:
                w[r] = 0.0
        for h in self.health:
            if h.quarantined:
                w[h.replica] = 0.0
        if w.sum() <= 0.0:
            # never vote with zero total mass: a fully degraded fleet falls
            # back to the raw staleness masses (all replicas re-enter)
            w = self._base_w.copy()
        return w

    def _next_attack_key(self):
        k = jax.random.fold_in(self._attack_key, self._attack_ctr)
        self._attack_ctr += 1
        return k

    # ------------------------------------------------------------------
    # jitted-step adapters (base-engine call signatures)
    # ------------------------------------------------------------------

    def _voted_first(self, logits, req_keys):
        nxt, scores = self._first_jit(logits, req_keys,
                                      jnp.asarray(self._vote_weights()),
                                      self._next_attack_key())
        return nxt

    def _voted_decode(self, params, cache, tokens, req_keys, gen_idx, *rest):
        out = self._decode_jit(
            params, cache, tokens, req_keys, gen_idx,
            jnp.asarray(self._w_now), self._next_attack_key(), *rest)
        nxt, scores, cache = out[:3]
        self._last_vm = out[3] if self._collect else None
        self._last_scores = scores
        return nxt, cache

    def _voted_unified(self, params, cache, tokens, row_slots, row_lens,
                       row_fresh, req_keys, tok_idx, *rest):
        out = self._unified_jit(
            params, cache, tokens, row_slots, row_lens, row_fresh, req_keys,
            tok_idx, jnp.asarray(self._w_now), self._next_attack_key(), *rest)
        nxt, scores, cache = out[:3]
        self._last_vm = out[3] if self._collect else None
        self._last_scores = scores
        return nxt, cache

    # ------------------------------------------------------------------
    # decode tick + quarantine policy
    # ------------------------------------------------------------------

    def _decode_tick(self) -> None:
        self._w_now = self._vote_weights()
        active = [s for s, r in self.slot_req.items() if not r.done]
        super()._decode_tick()
        self._after_vote(active)

    def _unified_tick(self) -> None:
        # decode rows sit at columns 0..S-1 of the unified batch (row index
        # == slot id), so the legacy health indexing scores[:, active] is
        # valid verbatim on mixed chunk batches too
        self._w_now = self._vote_weights()
        active = [s for s, r in self.slot_req.items() if not r.done]
        super()._unified_tick()
        self._after_vote(active)

    def _after_vote(self, active: List[int]) -> None:
        if self._obs is not None:
            step = self.report.decode_steps
            self._obs.metric("serve.replica.vote_mass", self._w_now,
                             step=step)
            if active and self._last_scores is not None:
                sc = np.asarray(self._last_scores)
                self._obs.metric("serve.replica.score",
                                 np.median(sc[:, active], axis=1), step=step)
            if self._collect and getattr(self, "_last_vm", None) is not None:
                self._obs.metric_tree(self._last_vm, step=step)
        if active and self._last_scores is not None:
            self._update_health(self._w_now, active,
                                np.asarray(self._last_scores))

    def _update_health(self, w: np.ndarray, active: List[int],
                       scores: np.ndarray) -> None:
        rc = self.rcfg
        step = self.report.decode_steps    # step just completed (1-based)
        # requests whose tokens this vote decided (finished slots may have
        # been released by the base tick already — guard the lookup)
        uids = sorted(self.slot_req[s].uid for s in active
                      if s in self.slot_req)
        for h in self.health:
            r = h.replica
            if h.quarantined:
                h.quarantined_tokens += 1
                h.backoff_remaining -= 1
                if h.backoff_remaining <= 0:
                    h.quarantined = False   # re-admission (probation: one
                    h.strikes = 0           # fresh run of strikes)
                    if self._obs is not None:
                        self._obs.event("serve.quarantine.readmit",
                                        step=step, replica=r,
                                        evictions=h.evictions)
                continue
            if w[r] <= 0.0:                 # dead / hanging this step
                h.tokens_missed += 1
                continue
            sc = float(np.median(scores[r, active]))
            h.tokens_voted += 1
            h.score_sum += sc
            h.score_n += 1
            if sc < rc.zeno_threshold:
                h.strikes += 1
                h.divergent_tokens += 1
            else:
                h.strikes = 0
            if h.strikes >= rc.quarantine_after:
                h.quarantined = True
                h.evictions += 1
                h.strikes = 0
                h.backoff_remaining = int(
                    rc.readmit_after * rc.backoff_factor ** (h.evictions - 1))
                if h.first_eviction_step is None:
                    h.first_eviction_step = step
                # keys are ADDITIVE on the pre-PR event dict (tests pin
                # replica/step/backoff): score at eviction + the request
                # uids whose streams the quarantined replica was voting on
                event = {"replica": r, "step": step,
                         "backoff": h.backoff_remaining,
                         "score": round(sc, 4), "requests": uids}
                self.report.quarantine_events.append(event)
                if self._obs is not None:
                    self._obs.event("serve.quarantine.evict", **event)

    def _finalize(self, reqs) -> ReplicatedServeReport:
        rep = super()._finalize(reqs)
        rep.replicas = [h.as_dict() for h in self.health]
        evicts = [h.first_eviction_step for h in self.health
                  if h.first_eviction_step is not None]
        rep.first_quarantine_step = min(evicts) if evicts else None
        return rep


def serve_replicated(cfg: ModelConfig, params, requests,
                     scfg: ServeConfig, rcfg: ReplicatedConfig,
                     engine: str = "continuous",
                     warmup: bool = True, obs=None) -> ReplicatedServeReport:
    """One-shot helper mirroring :func:`repro.serve.engine.serve`."""
    eng = ReplicatedServeEngine(cfg, params, scfg, rcfg, engine=engine,
                                obs=obs)
    return eng.run(requests, warmup=warmup)
