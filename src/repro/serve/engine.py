"""Continuous-batching serve engine.

``ServeEngine`` drives three jitted steps over the slot-mapped cache:

  prefill   — ``make_serve_prefill_step``: exact right-padded prefill of a
              bucketed prompt batch (static shapes: (prefill_batch, bucket)).
  insert    — ``cache.insert_prefill``: scatter the per-request cache rows
              into free slots (donated — in-place on the slot cache).
  decode    — ``make_decode_slots_step``: ONE token for ALL slots per call,
              each slot at its own depth (per-slot pos), with temperature /
              top-k sampling keyed by (request uid, token index) so sampled
              streams are identical regardless of slot assignment, batch
              composition or arrival order.

The DEFAULT continuous path is CHUNKED (``ServeConfig(chunked=True)``, no
explicit buckets): the trio above collapses into ONE jitted unified ragged
step (``make_unified_step`` → models/lm.py ``chunk_step``). Prompts stream
in fixed ``chunk_size`` chunks interleaved with decode — every tick runs
either a mixed ``(n_slots + chunk_rows, chunk_size)`` batch (decode rows at
columns 0..n_slots-1, row index == slot id; up to ``chunk_rows`` prefill
chunk rows behind them) or a decode-only ``(n_slots, 1)`` batch. Exactly
TWO compiles cover every workload (one per batch shape class), a long
prompt never stalls the decoding streams for a whole prefill call (TTFT),
and no prompt-length padding is ever computed. Explicit ``buckets``,
``engine="static"``, a mesh, or a non-token frontend fall back to the
legacy bucketed trio below.

``engine="static"`` runs the A/B baseline on the same jitted steps: one
fixed batch at a time — admission only when the engine is idle, no slot
retirement until the whole batch finishes — so short requests pay for the
longest request in their batch (the behaviour the ROADMAP item calls out).

``ServeConfig(paged=True)`` swaps the dense slot cache for the block-table
paged layout (cache.py): global-attention KV lives in fixed page pools, a
host-side ``PageAllocator`` hands each admitted request
``ceil((prompt + max_new) / page_size)`` physical pages, and admission is
bounded by free PAGES as well as free slots. The block table rides into the
jitted insert/decode steps as a small int32 argument (shape-static, so no
recompiles); freeing a slot just returns its pages and points its table row
at the dump page. Works for both engines; greedy outputs are token-identical
to the dense layout (pinned in tests/test_serve_engine.py).

Metrics are split into compile (warmup) / prefill / decode wall time;
`combined_tok_s` keeps the old serve launcher's single figure.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.steps import (make_decode_slots_step, make_serve_prefill_step,
                              make_unified_step, sample_next)
from repro.models.config import ModelConfig
from repro.serve.cache import (PageAllocator, SlotMap, init_paged_cache,
                               init_slot_cache, insert_prefill,
                               insert_prefill_paged, pages_per_slot)
from repro.serve.scheduler import (PrefillPlan, Request, Scheduler,
                                   default_buckets)

Pytree = Any

# reusable no-op span for uninstrumented engines (nullcontext is reentrant)
_NULL_CTX = contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 8
    max_len: int = 256              # slot capacity (prompt + generation)
    buckets: tuple = ()             # () -> chunked serving (DEFAULT); an
                                    # explicit tuple selects the legacy
                                    # bucketed-prefill trio
    chunked: bool = True            # unified ragged step + chunked prefill;
                                    # auto-disabled by static/mesh/buckets/
                                    # non-token frontends (legacy trio)
    chunk_size: int = 0             # prefill chunk width (tokens); 0 ->
                                    # page_size if paged else 16
    chunk_rows: int = 1             # max prefill chunk rows per mixed tick
                                    # (token budget = chunk_rows*chunk_size)
    max_prefill_batch: int = 4      # fixed prefill batch dim (dump-row padded)
    temperature: float = 0.0        # <= 0 -> greedy
    top_k: int = 0                  # 0 -> full vocab
    eos_id: Optional[int] = None    # None -> retire on max_new_tokens only
    seed: int = 0                   # sampling PRNG seed (per-request fold_in)
    paged: bool = False             # block-table paged KV cache (cache.py)
    page_size: int = 16             # KV rows per page
    n_pages: int = 0                # physical pool pages; 0 -> dense-equivalent
                                    # capacity (n_slots * pages_per_slot)


@dataclasses.dataclass
class ServeReport:
    engine: str
    n_requests: int = 0
    prefill_tokens: int = 0
    gen_tokens: int = 0
    compile_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    wall_s: float = 0.0             # serving wall time (compile excluded)
    decode_steps: int = 0
    decode_tok_s: float = 0.0       # useful generated tokens / decode wall
    prefill_tok_s: float = 0.0
    combined_tok_s: float = 0.0     # gen tokens / (compile+prefill+decode)
    latency_p50_s: float = 0.0      # request completion - arrival
    latency_p99_s: float = 0.0
    ttft_p50_s: float = 0.0         # first generated token - arrival
    ttft_p99_s: float = 0.0
    chunked: bool = False
    chunk_size: int = 0
    mean_occupancy: float = 0.0     # useful slot-rows per decode step
    paged: bool = False
    page_size: int = 0
    n_pages: int = 0                # physical pool pages (excl. dump page)
    mean_page_occupancy: float = 0.0  # pages in use per decode step / n_pages
    mean_pages_per_req: float = 0.0   # allocated pages per admitted request
    outputs: Dict[int, List[int]] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("outputs")
        return d


class ServeEngine:
    """Slot-mapped serving engine (``engine="continuous"`` or ``"static"``).

    ``mesh`` optionally threads the launch/specs.py decode shardings:
    params get the weight-stationary decode layout and the slot cache the
    dp-batched cache layout, with the decode output sharding pinned to the
    input so the cache round-trips in place.

    ``obs`` optionally attaches a :class:`repro.obs.RunObs`: per-call
    prefill/decode spans + wall-time metrics, queue depth, slot/page
    occupancy, and request lifecycle events (admit → async span → finish)
    land in its sink/tracer. ``None`` (default) is zero-cost — the jitted
    steps are identical and no host-side bookkeeping runs."""

    def __init__(self, cfg: ModelConfig, params: Pytree, scfg: ServeConfig,
                 engine: str = "continuous", mesh=None, obs=None):
        if not cfg.supports_decode():
            raise ValueError(f"{cfg.name} is encoder-only: no decode path")
        if engine not in ("continuous", "static"):
            raise ValueError(f"unknown engine {engine!r}")
        self.cfg = cfg
        self.scfg = scfg
        self.engine = engine
        # optional repro.obs.RunObs handle; None (default) is the zero-cost
        # path — every instrumentation site below is behind this guard
        self._obs = obs
        self._prefill_times: List[float] = []
        self._decode_times: List[float] = []
        self.static = engine == "static"
        S = scfg.n_slots
        # static mode prefills the whole batch at once; continuous packs up
        # to max_prefill_batch requests per (bucketed) prefill call
        self._prefill_batch = S if self.static else min(scfg.max_prefill_batch, S)
        # unified chunked path: continuous engine, single host, no explicit
        # buckets, token frontend — anything else keeps the legacy trio
        self.chunked = (scfg.chunked and not self.static and mesh is None
                        and not scfg.buckets and cfg.frontend == "none")
        if self.chunked:
            self.chunk_size = scfg.chunk_size or (scfg.page_size if scfg.paged
                                                  else 16)
            self.chunk_rows = max(1, min(scfg.chunk_rows, S))
            self.sched = Scheduler(None, self._prefill_batch)
        else:
            self.chunk_size = 0
            self.chunk_rows = 0
            self.sched = Scheduler(scfg.buckets or
                                   default_buckets(scfg.max_len),
                                   self._prefill_batch)
        self.slots = SlotMap(S)
        self.slot_req: Dict[int, Request] = {}
        self.paged = scfg.paged
        if self.paged:
            n_pages = scfg.n_pages or S * pages_per_slot(scfg.max_len,
                                                         scfg.page_size)
            self.pager = PageAllocator(S, scfg.max_len, scfg.page_size,
                                       n_pages)
        else:
            self.pager = None

        t, k = scfg.temperature, scfg.top_k
        self.prefilling: Dict[int, list] = {}    # slot -> [request, consumed]
        self._rr = 0                             # chunk-row round-robin cursor
        if self.chunked:
            self._unified = jax.jit(
                make_unified_step(cfg, t, k, paged=self.paged),
                donate_argnums=(1,))
            if self.paged:
                self.cache = init_paged_cache(cfg, S, scfg.max_len,
                                              scfg.page_size,
                                              self.pager.n_pages)
            else:
                self.cache = init_slot_cache(cfg, S, scfg.max_len)
        else:
            prefill_step = make_serve_prefill_step(cfg, scfg.max_len)
            decode_step = make_decode_slots_step(cfg, scfg.temperature,
                                                 scfg.top_k, paged=self.paged)

            def first_token(logits, req_keys):
                # prefill logits are (B, 1, V): already each request's last
                # real position; token index 0 keys the first sample
                return sample_next(logits[:, 0], req_keys,
                                   jnp.zeros(req_keys.shape[0], jnp.int32),
                                   t, k)

            if mesh is not None:
                if self.paged:
                    # paged pools shard over pages, not slots — wiring the
                    # page axis into cache_sharding is a ROADMAP follow-up
                    raise NotImplementedError("paged cache + mesh serving")
                from repro.dist.sharding import cache_sharding, param_sharding
                from repro.launch.specs import serve_cache_specs
                c_shard = cache_sharding(
                    cfg, mesh, serve_cache_specs(cfg, S, scfg.max_len))
                p_shard = param_sharding(cfg, mesh, params, mode="decode")
                params = jax.device_put(params, p_shard)
                self._prefill = jax.jit(prefill_step)
                self._insert = jax.jit(insert_prefill, donate_argnums=(0,),
                                       out_shardings=c_shard)
                # pin the cache output to its input layout: without this XLA
                # re-replicates the updated KV cache every decoded token
                self._decode = jax.jit(decode_step, donate_argnums=(1,),
                                       out_shardings=(None, c_shard))
                self.cache = jax.device_put(
                    init_slot_cache(cfg, S, scfg.max_len), c_shard)
            elif self.paged:
                self._prefill = jax.jit(prefill_step)
                self._insert = jax.jit(
                    functools.partial(insert_prefill_paged, cfg,
                                      scfg.page_size),
                    donate_argnums=(0,))
                self._decode = jax.jit(decode_step, donate_argnums=(1,))
                self.cache = init_paged_cache(cfg, S, scfg.max_len,
                                              scfg.page_size,
                                              self.pager.n_pages)
            else:
                self._prefill = jax.jit(prefill_step)
                self._insert = jax.jit(insert_prefill, donate_argnums=(0,))
                self._decode = jax.jit(decode_step, donate_argnums=(1,))
                self.cache = init_slot_cache(cfg, S, scfg.max_len)
            self._first = jax.jit(first_token)
        self.params = params

        self._base_key = jax.random.PRNGKey(scfg.seed)
        self.cur_tok = np.zeros((S,), np.int32)
        self.req_keys = np.zeros((S, 2), np.uint32)
        self.gen_idx = np.zeros((S,), np.int32)
        self.report = ServeReport(engine=engine, paged=self.paged,
                                  chunked=self.chunked,
                                  chunk_size=self.chunk_size)
        if self.paged:
            self.report.page_size = scfg.page_size
            self.report.n_pages = self.pager.n_pages
        self._occ_sum = 0.0
        self._page_occ_sum = 0.0
        self._pages_per_req: List[int] = []
        self._t_start = time.perf_counter()

    def _now(self) -> float:
        return time.perf_counter() - self._t_start

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _positions(self, req: Request) -> int:
        """Total sequence positions the request's prompt occupies."""
        extra = self.cfg.n_patches if self.cfg.frontend == "vision" else 0
        return req.prompt_len + extra

    def _pages_for(self, req: Request) -> int:
        """Worst-case KV pages the request pins (prompt + max_new span)."""
        return self.pager.pages_needed(
            self._positions(req) + req.max_new_tokens)

    def _validate(self, req: Request) -> None:
        """Admission constraints — shared by submit() and run()'s fail-fast
        pre-check so acceptance can never diverge between the two. Degenerate
        requests are rejected HERE, at submit time, with the uid in the
        message — never deep inside a prefill plan mid-serve."""
        toks = np.asarray(req.tokens)
        if toks.ndim != 1:
            raise ValueError(
                f"request {req.uid}: tokens must be a 1-D int sequence, "
                f"got shape {toks.shape}")
        if req.prompt_len < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}")
        if self.sched.buckets is not None and \
                req.prompt_len > self.sched.buckets[-1]:
            raise ValueError(
                f"request {req.uid}: prompt length {req.prompt_len} exceeds "
                f"the largest prefill bucket {self.sched.buckets[-1]}")
        if self._positions(req) + req.max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"request {req.uid}: prompt ({self._positions(req)}) + "
                f"max_new ({req.max_new_tokens}) exceeds max_len "
                f"{self.scfg.max_len}")
        if self.paged and self._pages_for(req) > self.pager.n_pages:
            raise ValueError(
                f"request {req.uid}: needs {self._pages_for(req)} pages "
                f"> pool size {self.pager.n_pages}")

    def submit(self, req: Request) -> None:
        self._validate(req)
        self.sched.submit(req)

    # ------------------------------------------------------------------
    # jitted-step drivers
    # ------------------------------------------------------------------

    def _req_key(self, uid: int) -> np.ndarray:
        return np.asarray(jax.random.fold_in(self._base_key, uid),
                          np.uint32)

    def _do_prefill(self, plan: PrefillPlan) -> None:
        cfg, B = self.cfg, self._prefill_batch
        n = len(plan.requests)
        assert n <= B
        toks = np.zeros((B, plan.bucket_len), np.int32)
        text_lens = np.ones((B,), np.int32)      # dump rows: length-1 prompts
        for i, r in enumerate(plan.requests):
            toks[i, :r.prompt_len] = r.tokens
            text_lens[i] = r.prompt_len
        batch = {"tokens": jnp.asarray(toks)}
        lens = text_lens.copy()
        if cfg.frontend == "vision":
            patches = np.zeros((B, cfg.n_patches, cfg.d_model), np.float32)
            for i, r in enumerate(plan.requests):
                if r.patches is not None:
                    patches[i] = r.patches
            batch["patches"] = jnp.asarray(patches, jnp.dtype(cfg.dtype))
            lens = lens + cfg.n_patches
        slot_ids = np.full((B,), self.slots.dump_slot, np.int32)
        keys = np.zeros((B, 2), np.uint32)
        for i, r in enumerate(plan.requests):
            slot_ids[i] = self.slots.alloc(r.uid)
            if self.paged:
                need = self._pages_for(r)
                self.pager.alloc(int(slot_ids[i]), need)
                self._pages_per_req.append(need)
            if self.scfg.temperature > 0.0:
                keys[i] = self._req_key(r.uid)

        if self._obs is not None:
            step_no = self.report.decode_steps
            for i, r in enumerate(plan.requests):
                self._obs.request_begin(r.uid, slot=int(slot_ids[i]),
                                        prompt_len=r.prompt_len)
                self._obs.event("serve.request.admit", step=step_no,
                                uid=r.uid, slot=int(slot_ids[i]),
                                prompt_len=r.prompt_len)

        t0 = time.perf_counter()
        with self._obs.span("prefill", n=n, bucket=plan.bucket_len) \
                if self._obs is not None else _NULL_CTX:
            logits, pcache = self._prefill(self.params, batch,
                                           jnp.asarray(lens))
            if self.paged:
                self.cache = self._insert(self.cache, pcache, slot_ids,
                                          jnp.asarray(self.pager.table))
            else:
                self.cache = self._insert(self.cache, pcache, slot_ids)
            first = np.asarray(self._first(logits, jnp.asarray(keys)))
            jax.block_until_ready(self.cache)
        dt = time.perf_counter() - t0
        self.report.prefill_s += dt
        self.report.prefill_tokens += int(text_lens[:n].sum())
        if self._obs is not None:
            self._prefill_times.append(dt)
            step_no = self.report.decode_steps
            self._obs.metric("serve.prefill_s", dt, step=step_no)
            self._obs.metric("serve.prefill_tokens",
                             self.report.prefill_tokens, step=step_no)
            self._obs.metric("serve.queue_depth", self.sched.n_waiting,
                             step=step_no)
            self._obs.counter("serve.queue", depth=self.sched.n_waiting)

        now = self._now()      # stamp AFTER the device work that produced it
        for i, r in enumerate(plan.requests):
            slot = int(slot_ids[i])
            tok = int(first[i])
            self.slot_req[slot] = r
            r.out_tokens.append(tok)
            r.t_first_token = now
            self.cur_tok[slot] = tok
            self.req_keys[slot] = keys[i]
            self.gen_idx[slot] = 1           # next sampled token's index
            self.report.gen_tokens += 1
            self._maybe_finish(slot, r, tok, now)

    def _maybe_finish(self, slot: int, r: Request, tok: int, now: float) -> None:
        eos = self.scfg.eos_id is not None and tok == self.scfg.eos_id
        if eos or len(r.out_tokens) >= r.max_new_tokens:
            r.t_finish = now
            if self._obs is not None:
                self._obs.event("serve.request.finish",
                                step=self.report.decode_steps, uid=r.uid,
                                slot=slot, gen_tokens=len(r.out_tokens),
                                eos=bool(eos))
                self._obs.request_end(r.uid, gen_tokens=len(r.out_tokens))
            if not self.static:
                self._release(slot)

    def _release(self, slot: int) -> None:
        del self.slot_req[slot]
        self.slots.free(slot)
        if self.paged:
            self.pager.free(slot)

    def _decode_tick(self) -> None:
        useful = sum(1 for r in self.slot_req.values() if not r.done)
        t0 = time.perf_counter()
        args = (self.params, self.cache, jnp.asarray(self.cur_tok[:, None]),
                jnp.asarray(self.req_keys), jnp.asarray(self.gen_idx))
        if self.paged:
            self._page_occ_sum += self.pager.occupancy
            args += (jnp.asarray(self.pager.table),)
        with self._obs.span("decode", slots=useful) \
                if self._obs is not None else _NULL_CTX:
            toks, self.cache = self._decode(*args)
            toks = np.asarray(toks)                  # host sync
        dt = time.perf_counter() - t0
        self.report.decode_s += dt
        self.report.decode_steps += 1
        self._occ_sum += useful / self.slots.n_slots
        if self._obs is not None:
            step_no = self.report.decode_steps
            occ = useful / self.slots.n_slots
            self._decode_times.append(dt)
            self._obs.metric("serve.decode_s", dt, step=step_no)
            self._obs.metric("serve.slot_occupancy", occ, step=step_no)
            self._obs.metric("serve.queue_depth", self.sched.n_waiting,
                             step=step_no)
            counters = {"depth": self.sched.n_waiting, "slots": occ}
            if self.paged:
                self._obs.metric("serve.page_occupancy",
                                 self.pager.occupancy, step=step_no)
                counters["pages"] = self.pager.occupancy
            self._obs.counter("serve.occupancy", **counters)

        now = self._now()      # stamp AFTER the device work that produced it
        for slot in list(self.slot_req):
            r = self.slot_req[slot]
            if r.done:                               # static: blocked slot
                continue
            tok = int(toks[slot])
            r.out_tokens.append(tok)
            self.cur_tok[slot] = tok
            self.gen_idx[slot] += 1
            self.report.gen_tokens += 1
            self._maybe_finish(slot, r, tok, now)
        if self.static and self.slot_req and \
                all(r.done for r in self.slot_req.values()):
            for slot in list(self.slot_req):         # whole batch retires
                self._release(slot)

    # ------------------------------------------------------------------
    # unified chunked path (self.chunked)
    # ------------------------------------------------------------------

    def _admit_chunked(self) -> None:
        """FCFS: pop head requests into free slots (paged: only while the
        head's worst-case page span fits) and start streaming their prompts
        through the unified step, ``chunk_size`` tokens per tick."""
        while self.slots.n_free and self.sched.n_waiting:
            head = self.sched.queue[0]
            if self.paged and self._pages_for(head) > self.pager.n_free:
                break                       # strict FCFS: wait for pages
            r = self.sched.queue.popleft()
            slot = self.slots.alloc(r.uid)
            if self.paged:
                need = self._pages_for(r)
                self.pager.alloc(slot, need)
                self._pages_per_req.append(need)
            self.prefilling[slot] = [r, 0]
            if self._obs is not None:
                self._obs.request_begin(r.uid, slot=slot,
                                        prompt_len=r.prompt_len)
                self._obs.event("serve.request.admit",
                                step=self.report.decode_steps, uid=r.uid,
                                slot=slot, prompt_len=r.prompt_len)

    def _unified_tick(self) -> None:
        """One unified-step call: every decoding slot advances one token and
        up to ``chunk_rows`` prefilling slots consume one prompt chunk each.
        Only two batch shapes ever run — mixed (S + chunk_rows, chunk_size)
        while any prompt is streaming, decode-only (S, 1) otherwise — so the
        compile count is per SHAPE CLASS, not per prompt length."""
        S, C = self.slots.n_slots, self.chunk_size
        mixed = bool(self.prefilling)
        Rn, W = (S + self.chunk_rows, C) if mixed else (S, 1)
        toks = np.zeros((Rn, W), np.int32)
        row_slots = np.full((Rn,), self.slots.dump_slot, np.int32)
        row_lens = np.ones((Rn,), np.int32)
        row_fresh = np.ones((Rn,), bool)
        keys = np.zeros((Rn, 2), np.uint32)
        tok_idx = np.zeros((Rn,), np.int32)
        # decode rows: row index == slot id (the replicated engine's health
        # indexing relies on this); inactive slots stay dump rows
        for slot in self.slot_req:
            toks[slot, 0] = self.cur_tok[slot]
            row_slots[slot] = slot
            row_fresh[slot] = False
            keys[slot] = self.req_keys[slot]
            tok_idx[slot] = self.gen_idx[slot]
        # chunk rows: round-robin over the prefilling slots so concurrent
        # long prompts make even progress (no intra-queue starvation)
        chunk_meta: List[tuple] = []     # (row, slot, take, finishing)
        if mixed:
            order = sorted(self.prefilling)
            start = self._rr % len(order)
            picked = [order[(start + i) % len(order)]
                      for i in range(min(self.chunk_rows, len(order)))]
            self._rr += len(picked)
            for j, slot in enumerate(picked):
                r, consumed = self.prefilling[slot]
                take = min(C, r.prompt_len - consumed)
                row = S + j
                toks[row, :take] = r.tokens[consumed:consumed + take]
                row_slots[row] = slot
                row_lens[row] = take
                row_fresh[row] = consumed == 0
                finishing = consumed + take >= r.prompt_len
                if finishing and self.scfg.temperature > 0.0:
                    keys[row] = self._req_key(r.uid)
                chunk_meta.append((row, slot, take, finishing))

        useful = len(self.slot_req)
        chunk_toks = sum(m[2] for m in chunk_meta)
        t0 = time.perf_counter()
        args = (self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(row_slots), jnp.asarray(row_lens),
                jnp.asarray(row_fresh), jnp.asarray(keys),
                jnp.asarray(tok_idx))
        if self.paged:
            args += (jnp.asarray(self.pager.table),)
        with self._obs.span("decode", slots=useful,
                            chunk_rows=len(chunk_meta),
                            chunk_tokens=chunk_toks) \
                if self._obs is not None else _NULL_CTX:
            nxt, self.cache = self._unified(*args)
            nxt = np.asarray(nxt)                    # host sync
        dt = time.perf_counter() - t0
        # split the step's wall time by token share: chunk tokens are
        # prefill work, decode rows decode work (one token each)
        frac = chunk_toks / max(1, chunk_toks + useful)
        self.report.prefill_s += dt * frac
        self.report.prefill_tokens += chunk_toks
        if useful:
            self.report.decode_s += dt * (1.0 - frac)
            self.report.decode_steps += 1
            self._occ_sum += useful / S
            if self.paged:
                self._page_occ_sum += self.pager.occupancy
        if self._obs is not None:
            step_no = self.report.decode_steps
            self._decode_times.append(dt)
            occ = useful / S
            self._obs.metric("serve.decode_s", dt, step=step_no)
            self._obs.metric("serve.slot_occupancy", occ, step=step_no)
            self._obs.metric("serve.queue_depth", self.sched.n_waiting,
                             step=step_no)
            counters = {"depth": self.sched.n_waiting, "slots": occ}
            if self.paged:
                self._obs.metric("serve.page_occupancy",
                                 self.pager.occupancy, step=step_no)
                counters["pages"] = self.pager.occupancy
            self._obs.counter("serve.occupancy", **counters)
            if chunk_toks:
                # the chunk rows' share of the tick is prefill work — same
                # proportional split the report uses
                self._prefill_times.append(dt * frac)
                self._obs.metric("serve.prefill_s", dt * frac, step=step_no)
                self._obs.metric("serve.prefill_tokens",
                                 self.report.prefill_tokens, step=step_no)

        now = self._now()      # stamp AFTER the device work that produced it
        for slot in list(self.slot_req):
            r = self.slot_req[slot]
            tok = int(nxt[slot])
            r.out_tokens.append(tok)
            self.cur_tok[slot] = tok
            self.gen_idx[slot] += 1
            self.report.gen_tokens += 1
            self._maybe_finish(slot, r, tok, now)
        # chunk rows: advance consumption; a row that just consumed its last
        # prompt token GRADUATES to decoding with its first sampled token
        for row, slot, take, finishing in chunk_meta:
            if not finishing:
                self.prefilling[slot][1] += take
                continue
            r, _ = self.prefilling.pop(slot)
            tok = int(nxt[row])
            self.slot_req[slot] = r
            r.out_tokens.append(tok)
            r.t_first_token = now
            self.cur_tok[slot] = tok
            self.req_keys[slot] = keys[row]
            self.gen_idx[slot] = 1           # next sampled token's index
            self.report.gen_tokens += 1
            self._maybe_finish(slot, r, tok, now)

    def _warmup_chunked(self) -> None:
        """Compile BOTH unified shape classes on all-dump-row batches —
        exactly two compiles, whatever the workload's prompt-length mix."""
        S = self.slots.n_slots
        for Rn, W in ((S + self.chunk_rows, self.chunk_size), (S, 1)):
            args = (self.params, self.cache, jnp.zeros((Rn, W), jnp.int32),
                    jnp.full((Rn,), self.slots.dump_slot, jnp.int32),
                    jnp.ones((Rn,), jnp.int32), jnp.ones((Rn,), bool),
                    jnp.zeros((Rn, 2), jnp.uint32),
                    jnp.zeros((Rn,), jnp.int32))
            if self.paged:
                args += (jnp.asarray(self.pager.table),)
            _, self.cache = self._unified(*args)
        jax.block_until_ready(self.cache)

    # ------------------------------------------------------------------
    # warmup (compile-time accounting)
    # ------------------------------------------------------------------

    def warmup(self, bucket_lens: Sequence[int]) -> float:
        """Compile every jitted shape on dummy data; the elapsed time is
        reported as ``compile_s`` so serving numbers exclude jit compiles.
        Chunked: both unified shape classes (two compiles, ``bucket_lens``
        ignored). Legacy: the decode step plus each (prefill, insert,
        first-token) bucket shape. Dump-row batches leave the (empty) engine
        state semantically untouched."""
        cfg, B = self.cfg, self._prefill_batch
        t0 = time.perf_counter()
        n_shapes = 2 if self.chunked else len(set(bucket_lens))
        ctx = (self._obs.span("warmup", buckets=n_shapes)
               if self._obs is not None else _NULL_CTX)
        with ctx:
            if self.chunked:
                self._warmup_chunked()
            else:
                self._warmup_body(bucket_lens)
        dt = time.perf_counter() - t0
        self.report.compile_s += dt
        return dt

    def _warmup_body(self, bucket_lens: Sequence[int]) -> None:
        cfg, B = self.cfg, self._prefill_batch
        for L in sorted({self.sched._bucket_for(l) for l in bucket_lens}):
            batch = {"tokens": jnp.zeros((B, L), jnp.int32)}
            lens = np.ones((B,), np.int32)
            if cfg.frontend == "vision":
                batch["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                             jnp.dtype(cfg.dtype))
                lens = lens + cfg.n_patches
            logits, pcache = self._prefill(self.params, batch,
                                           jnp.asarray(lens))
            dump_ids = np.full((B,), self.slots.dump_slot, np.int32)
            if self.paged:
                self.cache = self._insert(self.cache, pcache, dump_ids,
                                          jnp.asarray(self.pager.table))
            else:
                self.cache = self._insert(self.cache, pcache, dump_ids)
            self._first(logits, jnp.zeros((B, 2), jnp.uint32))
        dargs = (self.params, self.cache,
                 jnp.zeros((self.slots.n_slots, 1), jnp.int32),
                 jnp.zeros((self.slots.n_slots, 2), jnp.uint32),
                 jnp.zeros((self.slots.n_slots,), jnp.int32))
        if self.paged:
            dargs += (jnp.asarray(self.pager.table),)
        _, self.cache = self._decode(*dargs)
        jax.block_until_ready(self.cache)

    # ------------------------------------------------------------------
    # serving loop
    # ------------------------------------------------------------------

    def run(self, requests: Sequence[Request], warmup: bool = True
            ) -> ServeReport:
        """Serve ``requests`` (arrival times are wall-clock offsets from the
        start of the loop; pre-sorted or not) and return the report."""
        reqs = sorted(requests, key=lambda r: r.arrival)
        for r in reqs:          # fail fast — BEFORE paying the jit warmup
            self._validate(r)
        if warmup:
            self.warmup([r.prompt_len for r in reqs])
        pending = deque(reqs)
        self._t_start = time.perf_counter()
        while pending or self.sched.n_waiting or self.slots.n_active:
            now = self._now()
            while pending and pending[0].arrival <= now:
                self.submit(pending.popleft())
            if self.static:
                # fixed-batch baseline: admit only when the engine is idle
                if self.slots.n_active == 0 and self.sched.n_waiting:
                    take: List[Request] = []
                    budget = self.pager.n_free if self.paged else None
                    while self.sched.n_waiting and \
                            len(take) < self.slots.n_slots:
                        if budget is not None:
                            need = self._pages_for(self.sched.queue[0])
                            if need > budget:
                                break
                            budget -= need
                        take.append(self.sched.queue.popleft())
                    if take:
                        bucket = self.sched._bucket_for(
                            max(r.prompt_len for r in take))
                        self._do_prefill(PrefillPlan(take, bucket))
                    if self.slot_req and \
                            all(r.done for r in self.slot_req.values()):
                        for slot in list(self.slot_req):  # all max_new == 1
                            self._release(slot)
            elif self.chunked:
                self._admit_chunked()
            else:
                while self.slots.n_free and self.sched.n_waiting:
                    if self.paged:
                        plan = self.sched.plan_prefill(
                            self.slots.n_free,
                            page_budget=self.pager.n_free,
                            pages_for=self._pages_for)
                    else:
                        plan = self.sched.plan_prefill(self.slots.n_free)
                    if plan is None:   # head request waits for free pages
                        break
                    self._do_prefill(plan)
            if self.slots.n_active:
                if self.chunked:
                    self._unified_tick()
                else:
                    self._decode_tick()
            elif pending:
                time.sleep(min(1e-3, max(0.0, pending[0].arrival - now)))
        self.report.wall_s = self._now()
        return self._finalize(reqs)

    def _finalize(self, reqs: Sequence[Request]) -> ServeReport:
        rep = self.report
        rep.n_requests = len(reqs)
        rep.outputs = {r.uid: list(r.out_tokens) for r in reqs}
        lat = [r.t_finish - r.arrival for r in reqs if r.t_finish is not None]
        if lat:
            rep.latency_p50_s = float(np.percentile(lat, 50))
            rep.latency_p99_s = float(np.percentile(lat, 99))
        ttft = [r.t_first_token - r.arrival for r in reqs
                if r.t_first_token is not None]
        if ttft:
            rep.ttft_p50_s = float(np.percentile(ttft, 50))
            rep.ttft_p99_s = float(np.percentile(ttft, 99))
        if rep.decode_steps:
            rep.mean_occupancy = self._occ_sum / rep.decode_steps
            if self.paged:
                rep.mean_page_occupancy = self._page_occ_sum / rep.decode_steps
        if self._pages_per_req:
            rep.mean_pages_per_req = float(np.mean(self._pages_per_req))
        # first tokens come out of prefill; decode throughput counts the
        # tokens the decode loop itself produced
        decode_toks = rep.gen_tokens - rep.n_requests
        if rep.decode_s > 0:
            rep.decode_tok_s = decode_toks / rep.decode_s
        if rep.prefill_s > 0:
            rep.prefill_tok_s = rep.prefill_tokens / rep.prefill_s
        total = rep.compile_s + rep.prefill_s + rep.decode_s
        if total > 0:
            rep.combined_tok_s = rep.gen_tokens / total
        if self._obs is not None:
            from repro.obs.metrics import TIME_EDGES, bucketize
            step_no = rep.decode_steps
            self._obs.metric("serve.gen_tokens", rep.gen_tokens, step=step_no)
            if self._prefill_times:
                self._obs.metric("serve.prefill_s_hist",
                                 bucketize(self._prefill_times, TIME_EDGES),
                                 step=step_no)
            if self._decode_times:
                self._obs.metric("serve.decode_s_hist",
                                 bucketize(self._decode_times, TIME_EDGES),
                                 step=step_no)
        return rep


def serve(cfg: ModelConfig, params: Pytree, requests: Sequence[Request],
          scfg: ServeConfig, engine: str = "continuous", mesh=None,
          warmup: bool = True, obs=None) -> ServeReport:
    """One-shot helper: build an engine, serve the workload, return the report."""
    eng = ServeEngine(cfg, params, scfg, engine=engine, mesh=mesh, obs=obs)
    return eng.run(requests, warmup=warmup)
