"""repro.serve — continuous-batching decode engine.

Slot-mapped KV cache, dense or block-table paged (cache.py), bucketed FCFS
admission scheduler with slot + page budgets (scheduler.py) and the
ServeEngine (engine.py) driving jitted prefill → insert → decode-slots steps
with per-request streaming outputs. ``replicated.py`` layers the
Byzantine-tolerant R-replica engine on top: per-token weighted robust logit
voting (staleness-derived masses through ``repro.agg``) with fault injection
and Zeno++-style quarantine. See serve/README.md for the cache layouts,
scheduling policy and the vote pipeline.
"""
from repro.serve.cache import (PageAllocator, SlotMap, init_paged_cache,
                               init_slot_cache, insert_prefill,
                               insert_prefill_paged, pages_per_slot,
                               slot_hbm_bytes)
from repro.serve.engine import ServeConfig, ServeEngine, ServeReport, serve
from repro.serve.replicated import (ReplicaHealth, ReplicatedConfig,
                                    ReplicatedServeEngine,
                                    ReplicatedServeReport, serve_replicated,
                                    stale_params_stack)
from repro.serve.scheduler import (PrefillPlan, Request, Scheduler,
                                   default_buckets, synth_workload)

__all__ = [
    "PageAllocator", "PrefillPlan", "ReplicaHealth", "ReplicatedConfig",
    "ReplicatedServeEngine", "ReplicatedServeReport", "Request", "Scheduler",
    "ServeConfig", "ServeEngine", "ServeReport", "SlotMap", "default_buckets",
    "init_paged_cache", "init_slot_cache", "insert_prefill",
    "insert_prefill_paged", "pages_per_slot", "serve", "serve_replicated",
    "slot_hbm_bytes", "stale_params_stack", "synth_workload",
]
