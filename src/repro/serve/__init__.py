"""repro.serve — continuous-batching decode engine.

Slot-mapped KV cache (cache.py), bucketed FCFS admission scheduler
(scheduler.py) and the ServeEngine (engine.py) driving jitted
prefill → insert → decode-slots steps with per-request streaming outputs.
See serve/README.md for the cache layout and scheduling policy.
"""
from repro.serve.cache import SlotMap, init_slot_cache, insert_prefill
from repro.serve.engine import ServeConfig, ServeEngine, ServeReport, serve
from repro.serve.scheduler import (PrefillPlan, Request, Scheduler,
                                   default_buckets, synth_workload)

__all__ = [
    "PrefillPlan", "Request", "Scheduler", "ServeConfig", "ServeEngine",
    "ServeReport", "SlotMap", "default_buckets", "init_slot_cache",
    "insert_prefill", "serve", "synth_workload",
]
