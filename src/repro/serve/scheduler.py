"""Admission queue + continuous-batching scheduler.

Policy (see serve/README.md for the full table):

- FCFS admission — requests are prefilled strictly in queue order (no
  reordering, so no starvation); a shorter request behind a long one can
  only ride along in the SAME prefill batch, padded up to its bucket.
- Chunked prefill (DEFAULT — ``buckets=None``) — the engine streams each
  admitted prompt in fixed-size chunks through the unified ragged step
  between decode iterations, under a per-tick token budget of
  ``chunk_rows × chunk_size``; no prompt-length padding, no per-bucket
  recompiles (one compile per batch SHAPE CLASS), and a long prompt never
  stalls the decoding streams for a whole prefill call.
- Bucketed prefill (legacy — explicit ``buckets``) — prompts are padded to
  a small fixed set of lengths (powers of two by default) and the prefill
  batch dim is padded to a fixed size with dump rows, so the number of jit
  recompiles is bounded by ``len(buckets)`` regardless of the workload's
  length distribution.
- Slot admission — a prefill is planned only for as many requests as there
  are free slots; decode proceeds every engine tick for whatever slots are
  active, and slots retire independently on EOS / max_new_tokens.
- Page admission (paged cache) — with a ``page_budget``, each request must
  additionally fit its worst-case KV page need (``pages_for``); when the
  HEAD request does not fit, nothing is planned (still FCFS — the engine
  waits for retirements to return pages rather than jumping the queue).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Deque, List, NamedTuple, Optional, Sequence

import numpy as np


def default_buckets(max_prompt_len: int, min_bucket: int = 8) -> tuple[int, ...]:
    """Powers of two from ``min_bucket`` up to (and covering) max_prompt_len."""
    buckets = []
    b = min_bucket
    while b < max_prompt_len:
        buckets.append(b)
        b *= 2
    buckets.append(b)
    return tuple(buckets)


@dataclasses.dataclass
class Request:
    """One serving request. ``tokens`` is the int prompt; ``patches`` carries
    the precomputed vision-frontend embeddings for vlm archs (or None)."""
    uid: int
    tokens: np.ndarray
    max_new_tokens: int
    arrival: float = 0.0
    patches: Optional[np.ndarray] = None
    # filled in by the engine
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))

    @property
    def done(self) -> bool:
        return self.t_finish is not None


class PrefillPlan(NamedTuple):
    requests: List[Request]
    bucket_len: int         # padded token length for this prefill batch


class Scheduler:
    """FCFS admission queue. With ``buckets`` it produces padded bucketed
    prefill plans (legacy path); with ``buckets=None`` (chunked serving) it is
    a plain FCFS queue — the engine pulls head requests one at a time and
    streams them in chunks itself."""

    def __init__(self, buckets: Optional[Sequence[int]] = None,
                 max_prefill_batch: int = 4):
        self.buckets = tuple(sorted(buckets)) if buckets is not None else None
        self.max_prefill_batch = int(max_prefill_batch)
        self.queue: Deque[Request] = deque()

    def _bucket_for(self, prompt_len: int) -> int:
        if self.buckets is None:
            raise RuntimeError("scheduler has no prefill buckets (chunked "
                               "serving) — bucket_for is legacy-path only")
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt length {prompt_len} exceeds the largest bucket "
            f"{self.buckets[-1]}")

    def bucket_for(self, prompt_len: int) -> int:
        """Deprecated public alias — chunked serving has no buckets; legacy
        callers keep the exact padding + exceeded-bucket error semantics."""
        warnings.warn("Scheduler.bucket_for is deprecated; chunked serving "
                      "does not pad prompts to buckets", DeprecationWarning,
                      stacklevel=2)
        return self._bucket_for(prompt_len)

    def submit(self, req: Request) -> None:
        if self.buckets is not None:
            self._bucket_for(req.prompt_len)  # validate up front
        self.queue.append(req)

    @property
    def n_waiting(self) -> int:
        return len(self.queue)

    def plan_prefill(self, n_free_slots: int,
                     page_budget: Optional[int] = None,
                     pages_for=None) -> Optional[PrefillPlan]:
        """Pop up to min(free slots, max_prefill_batch) head-of-queue requests
        into one padded prefill batch. The bucket is the head request's; later
        requests join only if they fit it (FCFS — a long request is never
        jumped, it just starts its own batch next call). With a
        ``page_budget`` (paged cache), requests also join only while
        ``pages_for(req)`` fits the remaining budget; a head request that
        does not fit returns None (wait for retirements)."""
        if not self.queue or n_free_slots <= 0:
            return None
        k = min(n_free_slots, self.max_prefill_batch)
        head = self.queue[0]
        if page_budget is not None and pages_for(head) > page_budget:
            return None
        bucket = self._bucket_for(head.prompt_len)
        if page_budget is not None:
            page_budget -= pages_for(head)
        taken: List[Request] = [self.queue.popleft()]
        while self.queue and len(taken) < k and \
                self.queue[0].prompt_len <= bucket:
            if page_budget is not None:
                need = pages_for(self.queue[0])
                if need > page_budget:
                    break
                page_budget -= need
            taken.append(self.queue.popleft())
        return PrefillPlan(requests=taken, bucket_len=bucket)


def synth_workload(n_requests: int, vocab: int, *, seed: int = 0,
                   prompt_lens: tuple[int, int] = (8, 32),
                   gen_lens: tuple[int, int] = (4, 64),
                   short_frac: float = 0.8,
                   rate: float = 0.0,
                   n_patches: int = 0, d_model: int = 0) -> List[Request]:
    """Synthetic skewed-length workload shared by the launcher, the serve
    benchmark and the tests.

    Prompt lengths are uniform in ``prompt_lens``. Generation lengths are
    SKEWED: a ``short_frac`` fraction draws from the bottom quarter of
    ``gen_lens`` and the rest from the top quarter — the worst case for a
    static batch, where every short request pays for the longest one.
    ``rate`` > 0 gives Poisson arrivals (exponential inter-arrival gaps at
    ``rate`` req/s); 0 means everything arrives at t = 0. ``n_patches`` > 0
    attaches standard-normal vision-frontend embeddings of width d_model.

    Fully seed-deterministic: every draw category (arrivals, generation
    lengths, prompt lengths, prompt tokens, patches) gets its own
    ``default_rng([seed, k])`` stream, so the SAME seed yields the SAME
    prompts and lengths regardless of ``rate`` or ``n_patches`` — an
    arrival-rate A/B or a vision variant of a workload compares identical
    requests, and two calls with equal arguments are always identical."""
    r_arr, r_gen, r_plen, r_tok, r_pat = (
        np.random.default_rng([seed, k]) for k in range(5))
    lo_p, hi_p = prompt_lens
    lo_g, hi_g = gen_lens
    span = max(1, (hi_g - lo_g) // 4)
    t = 0.0
    reqs: List[Request] = []
    for uid in range(n_requests):
        if rate > 0:
            t += float(r_arr.exponential(1.0 / rate))
        short = r_gen.random() < short_frac
        gen = (int(r_gen.integers(lo_g, lo_g + span + 1)) if short
               else int(r_gen.integers(hi_g - span, hi_g + 1)))
        plen = int(r_plen.integers(lo_p, hi_p + 1))
        patches = (r_pat.standard_normal((n_patches, d_model))
                   .astype(np.float32) if n_patches else None)
        reqs.append(Request(
            uid=uid, arrival=t, max_new_tokens=gen, patches=patches,
            tokens=r_tok.integers(0, vocab, (plen,)).astype(np.int32)))
    return reqs
