"""Slot-mapped decode cache: fixed (S, max_len, ...) ring buffers + per-slot
position vector, donated in-place by the engine's jitted steps.

The device-side cache is the ordinary ``models.lm.init_cache`` pytree with two
twists: the leading batch dim is the number of SLOTS (requests map onto slots,
not batch rows), and ``cache["pos"]`` is a (S,) int32 vector — every slot
decodes at its own absolute depth (models/lm.py ``decode_step`` accepts both
the scalar and the vector form).

``insert_prefill`` scatters whole per-request cache rows (KV ring buffers,
SSM conv+state, RG-LRU conv+h, and pos) from a right-padded prefill into free
slots in one fused jitted call; a slot id equal to the slot count is the DUMP
index (out-of-bounds → mode="drop"), used for the padding rows that keep the
prefill batch shape static. Because the scatter overwrites EVERY leaf row of
the target slot — including the zero-filled tail beyond the request's true
length that the exact prefill emits — a freed slot's stale KV can never leak
into the request that reuses it.

Host-side bookkeeping (which slot belongs to which request) lives in
``SlotMap`` — a free-list allocator; the device never sees request identity.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import init_cache

Pytree = Any


def init_slot_cache(cfg: ModelConfig, n_slots: int, max_len: int) -> dict:
    """Decode cache with ``n_slots`` rows and a per-slot (S,) pos vector."""
    cache = init_cache(cfg, n_slots, max_len)
    cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def _top_key(path) -> Optional[str]:
    return getattr(path[0], "key", None) if path else None


def insert_prefill(cache: dict, pcache: dict, slot_ids) -> dict:
    """Scatter per-request prefill cache rows into slots.

    cache: slot cache (rows = S slots); pcache: the cache a right-padded
    ``prefill(..., lens=)`` emitted (rows = prefill batch); slot_ids: (Bp,)
    int32 target slot per prefill row, with ``n_slots`` acting as the dump
    index for padding rows. Leaves under ``groups`` carry the scanned-layer
    stack on axis 0, so their slot axis is axis 1 (same convention as
    dist/sharding.cache_sharding). Jit with ``donate_argnums=(0,)``."""
    slot_ids = jnp.asarray(slot_ids, jnp.int32)

    def put(path, leaf, prow):
        if _top_key(path) == "groups" and leaf.ndim >= 2:
            return leaf.at[:, slot_ids].set(prow.astype(leaf.dtype), mode="drop")
        return leaf.at[slot_ids].set(prow.astype(leaf.dtype), mode="drop")

    return jax.tree_util.tree_map_with_path(put, cache, pcache)


class SlotMap:
    """Host-side free-list slot allocator (alloc / free / occupancy)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))  # pop() -> 0 first
        self._owner: dict[int, int] = {}  # slot -> request uid

    @property
    def dump_slot(self) -> int:
        """Out-of-bounds slot id used to drop padding rows at insert."""
        return self.n_slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_active / self.n_slots

    def active_slots(self) -> List[int]:
        return sorted(self._owner)

    def owner(self, slot: int) -> int:
        return self._owner[slot]

    def alloc(self, uid: int) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop()
        self._owner[slot] = uid
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._owner:
            raise KeyError(f"slot {slot} is not allocated")
        del self._owner[slot]
        self._free.append(slot)
