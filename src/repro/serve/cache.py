"""Slot-mapped decode cache — dense and paged layouts — donated in-place by
the engine's jitted steps.

DENSE layout: the ordinary ``models.lm.init_cache`` pytree with two twists:
the leading batch dim is the number of SLOTS (requests map onto slots, not
batch rows), and ``cache["pos"]`` is a (S,) int32 vector — every slot decodes
at its own absolute depth (models/lm.py ``decode_step`` accepts both the
scalar and the vector form). Every slot reserves ``max_len`` KV rows per
global attention layer, whatever its request's real length.

PAGED layout (``init_paged_cache``): global/full attention layers swap the
``(S, max_len, KV, hd)`` rows for a fixed physical page pool
``(n_pages + 1, page_size, KV, hd)`` per layer — the LAST page is the dump
page — plus a host-side block table (``PageAllocator``) mapping each slot's
logical pages to physical ones. Cache HBM then scales with the sum of actual
sequence lengths (rounded up to pages), not ``n_slots × max_len``, and the
scheduler admits by free *pages*. Local (sliding-window) ring buffers, SSM
and RG-LRU states stay per-slot dense: they already scale with ``window`` /
O(1) state, so paging them would gain nothing (and would *lose* the ring's
bound for long decodes).

``insert_prefill`` scatters whole per-request cache rows (KV ring buffers,
SSM conv+state, RG-LRU conv+h, and pos) from a right-padded prefill into free
slots in one fused jitted call; a slot id equal to the slot count is the DUMP
index (out-of-bounds → mode="drop"), used for the padding rows that keep the
prefill batch shape static. Because the scatter overwrites EVERY leaf row of
the target slot — including the zero-filled tail beyond the request's true
length that the exact prefill emits — a freed slot's stale KV can never leak
into the request that reuses it. ``insert_prefill_paged`` does the same for
the paged layout, scattering each prefill position into its slot's page
``t // page_size`` row ``t % page_size``; positions past the slot's table
span (oversized buckets) and all padding rows land on the dump page. Paged
slot reuse is protected by *validity* rather than overwrite: a recycled page
is only ever read at positions ``<= pos``, all of which the new request has
re-written by then.

Host-side bookkeeping lives in ``SlotMap`` (free-list slot allocator) and
``PageAllocator`` (free-list page allocator + block table); the device never
sees request identity.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.lm import _kind_cache, init_cache, layer_plan

Pytree = Any


def init_slot_cache(cfg: ModelConfig, n_slots: int, max_len: int) -> dict:
    """Dense decode cache with ``n_slots`` rows and a per-slot (S,) pos."""
    cache = init_cache(cfg, n_slots, max_len)
    cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def pages_per_slot(max_len: int, page_size: int) -> int:
    """Logical pages covering ``max_len`` positions (block-table width)."""
    return -(-max_len // page_size)


def init_paged_cache(cfg: ModelConfig, n_slots: int, max_len: int,
                     page_size: int, n_pages: int) -> dict:
    """Paged decode cache: per-layer KV page pools for global attention,
    dense per-slot leaves for everything else (see module docstring)."""
    dtype = jnp.dtype(cfg.dtype)

    def kind_cache(kind):
        if kind in ("attn", "global"):
            kc = jnp.zeros((n_pages + 1, page_size, cfg.n_kv, cfg.hd), dtype)
            return (kc, kc)
        # local ring / SSM / RG-LRU: per-slot, identical to the dense layout
        return _kind_cache(cfg, kind, n_slots, max_len, dtype)

    prefix, n_full, rem = layer_plan(cfg)
    cache: dict = {"pos": jnp.zeros((n_slots,), jnp.int32)}
    if prefix:
        cache["prefix"] = [kind_cache(k) for k in prefix]
    if n_full:
        one = [kind_cache(k) for k in cfg.pattern]
        cache["groups"] = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (n_full,) + l.shape).copy(), one)
    if rem:
        cache["rem"] = [kind_cache(k) for k in rem]
    return cache


def _top_key(path) -> Optional[str]:
    return getattr(path[0], "key", None) if path else None


def _path_kind(cfg: ModelConfig, path) -> Optional[str]:
    """Layer kind ('attn'|'global'|'local'|'ssm'|'rec') a cache-leaf path
    belongs to, or None for the top-level pos vector."""
    top = _top_key(path)
    if top not in ("prefix", "groups", "rem"):
        return None
    idx = path[1].idx
    if top == "groups":
        return cfg.pattern[idx]
    prefix, _, rem = layer_plan(cfg)
    return (prefix if top == "prefix" else rem)[idx]


def insert_prefill(cache: dict, pcache: dict, slot_ids) -> dict:
    """Scatter per-request prefill cache rows into slots (dense layout).

    cache: slot cache (rows = S slots); pcache: the cache a right-padded
    ``prefill(..., lens=)`` emitted (rows = prefill batch); slot_ids: (Bp,)
    int32 target slot per prefill row, with ``n_slots`` acting as the dump
    index for padding rows. Leaves under ``groups`` carry the scanned-layer
    stack on axis 0, so their slot axis is axis 1 (same convention as
    dist/sharding.cache_sharding). Jit with ``donate_argnums=(0,)``."""
    slot_ids = jnp.asarray(slot_ids, jnp.int32)

    def put(path, leaf, prow):
        if _top_key(path) == "groups" and leaf.ndim >= 2:
            return leaf.at[:, slot_ids].set(prow.astype(leaf.dtype), mode="drop")
        return leaf.at[slot_ids].set(prow.astype(leaf.dtype), mode="drop")

    return jax.tree_util.tree_map_with_path(put, cache, pcache)


def insert_prefill_paged(cfg: ModelConfig, page_size: int, cache: dict,
                         pcache: dict, slot_ids, page_table) -> dict:
    """Scatter per-request prefill cache rows into the paged slot cache.

    Global-attention leaves scatter position ``t`` of prefill row ``b`` into
    physical page ``page_table[slot_ids[b], t // page_size]`` at row
    ``t % page_size``; positions whose logical page lies beyond the table
    span (bucket > pages_per_slot·page_size) and every padding row (slot id
    = dump row of the table) collapse onto the pool's dump page. All other
    leaves take the dense whole-row scatter. ``cfg`` and ``page_size`` are
    static — close over them (functools.partial) before jitting with
    ``donate_argnums`` on ``cache``."""
    slot_ids = jnp.asarray(slot_ids, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)
    pps = page_table.shape[1]

    def put(path, leaf, prow):
        kind = _path_kind(cfg, path)
        grouped = _top_key(path) == "groups"
        if kind in ("attn", "global"):
            dump = leaf.shape[1 if grouped else 0] - 1
            bucket = prow.shape[-3]
            t = jnp.arange(bucket)
            pj = t // page_size
            phys = jnp.where(pj[None, :] < pps,
                             page_table[slot_ids[:, None],
                                        jnp.minimum(pj, pps - 1)[None, :]],
                             dump)                       # (Bp, bucket)
            off = jnp.broadcast_to((t % page_size)[None, :], phys.shape)
            if grouped:
                return leaf.at[:, phys, off].set(prow.astype(leaf.dtype))
            return leaf.at[phys, off].set(prow.astype(leaf.dtype))
        if grouped and leaf.ndim >= 2:
            return leaf.at[:, slot_ids].set(prow.astype(leaf.dtype), mode="drop")
        return leaf.at[slot_ids].set(prow.astype(leaf.dtype), mode="drop")

    return jax.tree_util.tree_map_with_path(put, cache, pcache)


def slot_hbm_bytes(cfg: ModelConfig, max_len: int,
                   kv_rows: Optional[int] = None) -> int:
    """Decode-cache HBM bytes ONE request pins while resident.

    ``kv_rows=None`` is the dense layout: every global attention layer holds
    ``max_len`` KV rows for the slot. ``kv_rows=r`` is the paged layout: the
    request's global layers hold only its ``r`` allocated page rows. Local
    ring (``window`` rows), SSM and RG-LRU state costs are identical in both
    layouts. Used by benchmarks/bench_serve.py for the dense-vs-paged
    memory-accounting A/B."""
    bpe = jnp.dtype(cfg.dtype).itemsize
    kv_row = 2 * cfg.n_kv * cfg.hd * bpe                # K + V
    total = 0
    for kind in cfg.layer_kinds():
        if kind == "local":
            total += cfg.window * kv_row
        elif kind == "ssm":
            di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
            total += (cfg.conv_width - 1) * (di + 2 * N) * bpe
            total += H * (di // H) * N * 4              # f32 recurrent state
        elif kind == "rec":
            w = cfg.lru_width or cfg.d_model
            total += (cfg.conv_width - 1) * w * bpe + w * 4
        else:
            total += (max_len if kv_rows is None else kv_rows) * kv_row
    return total


class SlotMap:
    """Host-side free-list slot allocator (alloc / free / occupancy)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))  # pop() -> 0 first
        self._owner: dict[int, int] = {}  # slot -> request uid

    @property
    def dump_slot(self) -> int:
        """Out-of-bounds slot id used to drop padding rows at insert."""
        return self.n_slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_active / self.n_slots

    def active_slots(self) -> List[int]:
        return sorted(self._owner)

    def owner(self, slot: int) -> int:
        return self._owner[slot]

    def alloc(self, uid: int) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop()
        self._owner[slot] = uid
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._owner:
            raise KeyError(f"slot {slot} is not allocated")
        del self._owner[slot]
        self._free.append(slot)


class PageAllocator:
    """Host-side free-list page allocator + block table.

    ``table`` is the (n_slots + 1, pages_per_slot) int32 block table handed
    to the jitted steps each call: row ``s`` maps slot ``s``'s logical pages
    to physical pool pages; unallocated entries — and the entire extra DUMP
    row used for prefill padding — hold ``n_pages`` (the pool's dump page).
    Pages are claimed for a request's full worst-case span
    (``pages_needed(prompt + max_new)``) at admission and returned when the
    slot retires; on-demand growth + preemption is a ROADMAP follow-up."""

    def __init__(self, n_slots: int, max_len: int, page_size: int,
                 n_pages: int):
        self.page_size = page_size
        self.n_pages = n_pages
        self.pages_per_slot = pages_per_slot(max_len, page_size)
        self.dump_page = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._held: dict[int, List[int]] = {}  # slot -> physical page ids
        self.table = np.full((n_slots + 1, self.pages_per_slot), n_pages,
                             np.int32)

    def pages_needed(self, seq_len: int) -> int:
        return pages_per_slot(seq_len, self.page_size)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_used / self.n_pages

    def alloc(self, slot: int, n: int) -> List[int]:
        """Claim ``n`` physical pages as slot ``slot``'s logical pages 0..n-1."""
        if slot in self._held:
            raise RuntimeError(f"slot {slot} already holds pages")
        if n > self.pages_per_slot:
            raise ValueError(f"need {n} pages > pages_per_slot "
                             f"{self.pages_per_slot}")
        if n > len(self._free):
            raise RuntimeError(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        self._held[slot] = pages
        self.table[slot, :n] = pages
        self.table[slot, n:] = self.dump_page
        return pages

    def free(self, slot: int) -> None:
        if slot not in self._held:
            raise KeyError(f"slot {slot} holds no pages")
        self._free.extend(self._held.pop(slot))
        self.table[slot, :] = self.dump_page
