"""Minimal, dependency-free stand-in for the subset of `hypothesis` used by
this repo's property tests (tests/test_aggregators.py, tests/test_kernels.py).

Loaded by tests/conftest.py ONLY when the real `hypothesis` package is not
installed (this container has no network/pip access — see
requirements-dev.txt). The real library is strictly preferred: it shrinks
counterexamples and explores edge cases adaptively; this fallback just draws
`max_examples` deterministic pseudo-random examples per test, which is enough
to keep the property suites meaningful offline.

Supported API: @given, @settings(max_examples=, deadline=), strategies.
{integers, floats, lists, sampled_from, composite}.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib
from typing import Any, Callable

import numpy as np


class SearchStrategy:
    """A strategy is just a draw function rng -> value here."""

    def __init__(self, draw_fn: Callable[[np.random.Generator], Any]):
        self._draw = draw_fn

    def do_draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def lists(elements: SearchStrategy, *, min_size: int = 0, max_size: int = 10
          ) -> SearchStrategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.do_draw(rng) for _ in range(n)]

    return SearchStrategy(draw)


def sampled_from(seq) -> SearchStrategy:
    seq = list(seq)
    return SearchStrategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def composite(f: Callable) -> Callable[..., SearchStrategy]:
    @functools.wraps(f)
    def builder(*args, **kwargs) -> SearchStrategy:
        def draw_value(rng):
            def draw(strategy: SearchStrategy):
                return strategy.do_draw(rng)

            return f(draw, *args, **kwargs)

        return SearchStrategy(draw_value)

    return builder


class settings:
    """Decorator recording (max_examples, deadline); applied above @given."""

    def __init__(self, max_examples: int = 50, deadline=None, **_kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(*strategies: SearchStrategy):
    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None)
            n = cfg.max_examples if cfg is not None else 50
            for i in range(n):
                # stable per-(test, example) seed so failures reproduce
                seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}:{i}".encode())
                rng = np.random.default_rng(seed)
                values = [s.do_draw(rng) for s in strategies]
                fn(*args, *values, **kwargs)

        wrapper.is_hypothesis_test = True
        # pytest must not see the strategy-filled parameters as fixtures
        wrapper.__signature__ = inspect.Signature()
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return decorator


# Expose a module-like `strategies` attribute so both import styles work:
#   from hypothesis import strategies as st
#   import hypothesis.strategies as st   (conftest registers it in sys.modules)
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.lists = lists
strategies.sampled_from = sampled_from
strategies.composite = composite
strategies.SearchStrategy = SearchStrategy

HealthCheck = types.SimpleNamespace(too_slow="too_slow", data_too_large="data_too_large",
                                    filter_too_much="filter_too_much")


def install() -> None:
    """Register this module as `hypothesis` in sys.modules (gated by conftest)."""
    mod = sys.modules[__name__]
    sys.modules.setdefault("hypothesis", mod)
    sys.modules.setdefault("hypothesis.strategies", strategies)
