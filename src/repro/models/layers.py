"""Core transformer layers: RMSNorm, RoPE, GQA attention (full / sliding /
cached decode), gated MLP. Pure functions over param dicts."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

Array = jnp.ndarray


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, H * hd)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, KV * hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, KV * hd)) * sc).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * hd, d)) * (H * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qkv(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array, Array]:
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(cfg: ModelConfig, q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """Grouped-query scaled dot-product attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); mask: broadcastable to
    (B, H, Sq, Sk) with True = attend.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * (hd ** -0.5)
    if mask is not None:
        # mask (B|1, 1, Sq, Sk) -> (B, KV, G, Sq, Sk)
        scores = jnp.where(mask[:, :, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H * hd)


def make_mask(cfg: ModelConfig, S: int, kind: str, dtype=bool) -> Optional[Array]:
    """(1, 1, S, S) attention mask. kind: attn|global (full or causal), local
    (causal sliding window)."""
    if not cfg.causal and kind in ("attn", "global"):
        return None  # bidirectional encoder
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    m = k_pos <= q_pos
    if kind == "local" and cfg.window > 0:
        m = m & (k_pos > q_pos - cfg.window)
    return m[None, None]


def attention(p: dict, cfg: ModelConfig, x: Array, kind: str,
              positions: Optional[Array] = None) -> Array:
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    pos = positions if positions is not None else jnp.arange(S)
    cos, sin = rope_angles(pos, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    mask = make_mask(cfg, S, kind)
    out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def attention_decode(p: dict, cfg: ModelConfig, x: Array, kind: str,
                     k_cache: Array, v_cache: Array, pos: Array
                     ) -> tuple[Array, Array, Array]:
    """Single-token decode. x: (B, 1, d). Caches: (B, W, KV, hd) where W is the
    full seq length (global layers) or the sliding window (local layers, ring
    buffer indexed by pos % W). pos: () int32 — current absolute position — or
    (B,) int32 for slot-mapped serving, where each row decodes at its own
    depth (repro.serve continuous batching).
    Returns (out, k_cache, v_cache)."""
    B = x.shape[0]
    W = k_cache.shape[1]
    q, k, v = _qkv(p, cfg, x)
    pos = jnp.asarray(pos)
    per_slot = pos.ndim > 0
    cos, sin = rope_angles(pos[:, None] if per_slot else pos[None],
                           cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = (pos % W) if kind == "local" else jnp.minimum(pos, W - 1)
    if per_slot:
        rows = jnp.arange(B)
        k_cache = k_cache.at[rows, slot].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, slot].set(v[:, 0].astype(v_cache.dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    if cfg.use_pallas_decode and W % min(128, W) == 0:
        # flash-decode kernel: streams the cache through VMEM once; handles
        # scalar AND per-slot (B,) pos (the index map routes each row's pos)
        from repro.kernels.swa import swa_decode_pallas
        out = swa_decode_pallas(q[:, 0], k_cache, v_cache, pos,
                                local=(kind == "local"),
                                block_w=min(128, W),
                                interpret=cfg.pallas_interpret)
        out = out.reshape(B, 1, -1).astype(x.dtype)
    else:
        # validity: ring slots written so far (local) / prefix (global)
        idx = jnp.arange(W)
        pb = pos[:, None] if per_slot else pos  # (B,1) | ()
        if kind == "local":
            valid = (idx <= pb % W) | (pb >= W)  # all slots valid once wrapped
        else:
            valid = idx <= pb
        # (B,1,1,W) per-slot / (1,1,1,W) shared
        mask = valid[:, None, None, :] if per_slot else valid[None, None, None, :]
        out = _sdpa(cfg, q, k_cache, v_cache, mask)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), k_cache, v_cache


def attention_decode_paged(p: dict, cfg: ModelConfig, x: Array,
                           k_pool: Array, v_pool: Array, page_table: Array,
                           pos: Array) -> tuple[Array, Array, Array]:
    """Single-token decode against a paged (block-table) KV pool — the serve
    path for global/full-attention layers (local layers keep the dense ring:
    their cache already scales with ``window``, not ``max_len``).

    x: (S, 1, d) — one row per SLOT. k/v_pool: (n_pages + 1, page_size, KV,
    hd) physical page pools whose last page is the dump page. page_table:
    (≥S, pages_per_slot) int32 — each slot's logical→physical page map, with
    unallocated entries (and every entry of a free slot's row) pointing at
    the dump page. pos: (S,) int32 per-slot absolute position. The new KV is
    scattered into page ``pos // page_size`` row ``pos % page_size``; free
    slots land on the dump page. Returns (out, k_pool, v_pool)."""
    S = x.shape[0]
    P = k_pool.shape[1]
    pps = page_table.shape[1]
    q, k, v = _qkv(p, cfg, x)
    pos = jnp.asarray(pos)
    cos, sin = rope_angles(pos[:, None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # free slots can run pos past the table span; the clamp is safe because
    # their table rows are all dump — active slots never exceed their pages
    lp = jnp.minimum(pos // P, pps - 1)
    phys = page_table[jnp.arange(S), lp]
    off = pos % P
    k_pool = k_pool.at[phys, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[phys, off].set(v[:, 0].astype(v_pool.dtype))
    if cfg.use_pallas_decode:
        from repro.kernels.swa import paged_decode_pallas
        out = paged_decode_pallas(q[:, 0], k_pool, v_pool, page_table, pos,
                                  interpret=cfg.pallas_interpret)
        out = out.reshape(S, 1, -1).astype(x.dtype)
    else:
        # jnp oracle: gather the slot's pages dense, then masked SDPA
        pages = page_table[:S]                            # (S, pps)
        kg = k_pool[pages].reshape(S, pps * P, cfg.n_kv, cfg.hd)
        vg = v_pool[pages].reshape(S, pps * P, cfg.n_kv, cfg.hd)
        valid = jnp.arange(pps * P)[None, :] <= pos[:, None]
        out = _sdpa(cfg, q, kg, vg, valid[:, None, None, :])
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), k_pool, v_pool


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wg": (jax.random.normal(ks[0], (d_model, d_ff)) * d_model ** -0.5).astype(dtype),
        "wu": (jax.random.normal(ks[1], (d_model, d_ff)) * d_model ** -0.5).astype(dtype),
        "wd": (jax.random.normal(ks[2], (d_ff, d_model)) * d_ff ** -0.5).astype(dtype),
    }


def mlp(p: dict, x: Array) -> Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["wd"])
