"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block in JAX.

Chunked SSD algorithm: within a chunk the recurrence is computed in its dual
"masked attention" quadratic form (MXU-friendly); across chunks a linear state
recurrence carries (H, P, N) states. Decode keeps an O(1) recurrent state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Array = jnp.ndarray


def init_ssm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.n_ssm_heads
    N = cfg.ssm_state
    ks = jax.random.split(key, 5)
    # in_proj emits [z (di), x (di), B (N), C (N), dt (H)]  (single B/C group)
    proj_out = 2 * di + 2 * N + H
    p = {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di + 2 * N)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "d_skip": jnp.ones((H,), dtype),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * di ** -0.5).astype(dtype),
    }
    return p


def _segsum(a: Array) -> Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = sum_{k=j+1..i} a[..., k]."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array, chunk: int,
                init_state: Array | None = None) -> tuple[Array, Array]:
    """SSD scan.

    x:  (b, s, h, p) input heads
    dt: (b, s, h)    positive step sizes
    A:  (h,)         negative decay rates (continuous-time)
    Bm: (b, s, n)    input projection (single group, shared across heads)
    Cm: (b, s, n)    output projection
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    c = chunk
    nc = s // c
    assert s % c == 0, "sequence must be divisible by the SSD chunk"

    a = dt * A[None, None, :]                     # (b, s, h) log-decay per step (<0)
    xb = (x * dt[..., None]).reshape(b, nc, c, h, p)
    a = a.reshape(b, nc, c, h)
    B = Bm.reshape(b, nc, c, n)
    C = Cm.reshape(b, nc, c, n)

    a_hc = jnp.moveaxis(a, -1, -2)                # (b, nc, h, c)
    a_cum = jnp.cumsum(a_hc, axis=-1)             # (b, nc, h, c)

    # --- intra-chunk (dual quadratic form) ---
    L = jnp.exp(_segsum(a_hc))                    # (b, nc, h, c, c)
    scores = jnp.einsum("bzin,bzjn->bzij", C, B)  # (b, nc, c, c)
    y_diag = jnp.einsum("bzij,bzhij,bzjhp->bzihp", scores, L, xb)

    # --- chunk states ---
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)          # (b, nc, h, c)
    states = jnp.einsum("bzcn,bzhc,bzchp->bzhpn", B, decay_states, xb)

    # --- inter-chunk recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(a_cum[..., -1])                     # (b, nc, h)
    s0 = jnp.zeros((b, h, p, n), x.dtype) if init_state is None else init_state

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    last, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (b, nc, h, p, n)

    # --- inter-chunk output ---
    state_decay = jnp.exp(a_cum)                              # (b, nc, h, c)
    y_off = jnp.einsum("bzcn,bzhpn,bzhc->bzchp", C, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, last


class SSMCache(NamedTuple):
    conv: Array   # (B, conv_width-1, di + 2N) rolling conv inputs
    state: Array  # (B, H, P, N)


def _conv1d(seq: Array, w: Array, b: Array) -> Array:
    """Causal depthwise conv. seq: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + seq.shape[1], :] * w[i] for i in range(K))
    return out + b


def ssm_block(p: dict, cfg: ModelConfig, x: Array) -> Array:
    """Full-sequence forward. x: (B, S, d)."""
    B_, S, _ = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    zxbcdt = jnp.einsum("bsd,do->bso", x, p["in_proj"])
    z, xc, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(_conv1d(conv_in, p["conv_w"], p["conv_b"]))
    xc, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xc.reshape(B_, S, H, P)
    if cfg.use_pallas_ssm and S % cfg.ssm_chunk == 0:
        from repro.kernels.ops import ssd_scan
        y, _ = ssd_scan(xh.astype(jnp.float32), dt, A, Bc.astype(jnp.float32),
                        Cc.astype(jnp.float32), cfg.ssm_chunk,
                        interpret=cfg.pallas_interpret)
    else:
        y, _ = ssd_chunked(xh.astype(jnp.float32), dt, A, Bc.astype(jnp.float32),
                           Cc.astype(jnp.float32), cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)  # gated norm (simplified RMSNorm-gate)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, di + 2 * N), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
    )


def ssm_decode(p: dict, cfg: ModelConfig, x: Array, cache: SSMCache
               ) -> tuple[Array, SSMCache]:
    """Single-token decode. x: (B, 1, d)."""
    B_ = x.shape[0]
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    zxbcdt = jnp.einsum("bsd,do->bso", x, p["in_proj"])[:, 0]
    z, xc, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)          # (B, C)
    hist = jnp.concatenate([cache.conv, conv_in[:, None]], axis=1)  # (B, K, C)
    w = p["conv_w"]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"])
    new_conv = hist[:, 1:]
    xc, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B, H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                           # (B, H)
    xh = xc.reshape(B_, H, P).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, Bc.astype(jnp.float32), dt)
    state = cache.state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cc.astype(jnp.float32))
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B_, di).astype(x.dtype) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None]
    return out, SSMCache(conv=new_conv, state=state)
