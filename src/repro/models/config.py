"""Unified architecture configuration covering all assigned families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"     # dense | moe | ssm | hybrid | encoder | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 4
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True          # False for encoder-only (hubert)
    # local/global attention pattern: `window > 0` enables sliding-window layers;
    # every `global_every`-th layer (1-based) is full/global attention.
    window: int = 0
    global_every: int = 0        # 0 -> all layers share `window` (or all full)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_dispatch: str = "dense"  # dense | sharded (shard_map local dispatch)
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4
    # hybrid (RecurrentGemma): repeating block pattern, e.g. ("rec","rec","attn")
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0           # 0 -> d_model
    # modality frontend stub
    frontend: str = "none"       # none | audio | vision
    n_patches: int = 256         # vision: patches prepended to the text sequence
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # distribution policy
    fsdp: bool = False           # shard large-matrix non-model dims over pod×data
    moe_shard: str = "ep"        # ep: experts over model axis | tp: expert-hidden over model
    dtype: str = "float32"       # parameter / activation dtype
    scan_layers: bool = True     # stack+scan homogeneous layer groups
    remat: bool = False          # activation checkpointing on each layer group
    # Pallas kernel integration (TPU target; interpret=True on CPU)
    use_pallas_decode: bool = False   # flash decode (kernels/swa.py) in attention_decode
    use_pallas_ssm: bool = False      # SSD intra-chunk kernel (kernels/ssd.py)
    pallas_interpret: bool = True     # False on real TPUs

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Per-layer kind pattern of length == one repeating group."""
        if self.arch_type == "hybrid" and self.block_pattern:
            return self.block_pattern
        if self.arch_type == "ssm":
            return ("ssm",)
        if self.global_every and self.window:
            # gemma3-style: (global_every - 1) local layers then 1 global
            return tuple(["local"] * (self.global_every - 1) + ["global"])
        if self.window:
            return ("local",)
        return ("attn",)

    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def is_subquadratic(self) -> bool:
        """True if a 500k-token decode is feasible (no full-attention KV growth),
        i.e. every layer is local/recurrent/ssm OR global layers are O(S)-decode
        with a sliding-window majority (gemma3's 5:1)."""
        kinds = set(self.layer_kinds())
        return kinds.issubset({"ssm", "rec", "local"}) or (
            "local" in kinds and self.window > 0
        )

    def supports_decode(self) -> bool:
        return self.causal and self.arch_type not in ("encoder", "audio")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
