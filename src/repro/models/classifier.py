"""Small classifiers for the paper-faithful experiments (Appendix D).

``cnn`` mirrors the paper's two-conv architecture; ``mlp`` is a cheap variant
for fast CI benchmarks. Pure functions over param dicts; losses are
cross-entropy as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    name: str = "paper-cnn"
    kind: str = "cnn"              # cnn | mlp
    image_hw: Tuple[int, int] = (28, 28)
    channels: int = 1
    n_classes: int = 10
    conv_filters: Tuple[int, int] = (20, 50)
    fc_hidden: int = 50
    mlp_hidden: Tuple[int, ...] = (64,)


def _conv_out_hw(cfg: ClassifierConfig) -> Tuple[int, int]:
    h, w = cfg.image_hw
    h = (h - 4) // 2  # conv 5x5 valid + maxpool 2
    w = (w - 4) // 2
    h = (h - 4) // 2
    w = (w - 4) // 2
    return h, w


def init_classifier(key, cfg: ClassifierConfig) -> dict:
    ks = jax.random.split(key, 6)
    if cfg.kind == "mlp":
        dims = (cfg.image_hw[0] * cfg.image_hw[1] * cfg.channels,
                *cfg.mlp_hidden, cfg.n_classes)
        return {f"w{i}": jax.random.normal(ks[i], (a, b)) * a ** -0.5
                for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))} | {
                f"b{i}": jnp.zeros((b,)) for i, b in enumerate(dims[1:])}
    c1, c2 = cfg.conv_filters
    h, w = _conv_out_hw(cfg)
    flat = h * w * c2
    return {
        "conv1": jax.random.normal(ks[0], (c1, cfg.channels, 5, 5)) * (25 * cfg.channels) ** -0.5,
        "bc1": jnp.zeros((c1,)),
        "conv2": jax.random.normal(ks[1], (c2, c1, 5, 5)) * (25 * c1) ** -0.5,
        "bc2": jnp.zeros((c2,)),
        "fc1": jax.random.normal(ks[2], (flat, cfg.fc_hidden)) * flat ** -0.5,
        "bf1": jnp.zeros((cfg.fc_hidden,)),
        "norm_scale": jnp.ones((cfg.fc_hidden,)),
        "norm_bias": jnp.zeros((cfg.fc_hidden,)),
        "fc2": jax.random.normal(ks[3], (cfg.fc_hidden, cfg.n_classes)) * cfg.fc_hidden ** -0.5,
        "bf2": jnp.zeros((cfg.n_classes,)),
    }


def _maxpool2(x: Array) -> Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def apply_classifier(params: dict, cfg: ClassifierConfig, x: Array) -> Array:
    """x: (B, H, W, C) -> logits (B, n_classes)."""
    if cfg.kind == "mlp":
        h = x.reshape(x.shape[0], -1)
        n = len([k for k in params if k.startswith("w")])
        for i in range(n):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < n - 1:
                h = jax.nn.relu(h)
        return h
    h = jnp.transpose(x, (0, 3, 1, 2))  # NCHW
    h = jax.lax.conv_general_dilated(h, params["conv1"], (1, 1), "VALID") + params["bc1"][None, :, None, None]
    h = _maxpool2(jax.nn.relu(h))
    h = jax.lax.conv_general_dilated(h, params["conv2"], (1, 1), "VALID") + params["bc2"][None, :, None, None]
    h = _maxpool2(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    h = h @ params["fc1"] + params["bf1"]
    # batch-norm-like normalization (inference-style, per feature)
    mu = jnp.mean(h, axis=0, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=0, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"] + params["norm_bias"]
    h = jax.nn.relu(h)
    return h @ params["fc2"] + params["bf2"]


def classifier_loss(params: dict, cfg: ClassifierConfig, batch: dict) -> Array:
    logits = apply_classifier(params, cfg, batch["x"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=-1))


def classifier_accuracy(params: dict, cfg: ClassifierConfig, batch: dict) -> Array:
    logits = apply_classifier(params, cfg, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
