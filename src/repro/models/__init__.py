from .config import ModelConfig  # noqa: F401
from .lm import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_lm,
    lm_loss,
    param_count,
    prefill,
)
