"""Model zoo: unified ModelConfig + decoder-LM assembly (dense / MoE / SSM /
griffin hybrids / encoder / vlm) with forward, prefill and decode modes."""
from .config import ModelConfig  # noqa: F401
from .lm import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_lm,
    lm_loss,
    param_count,
    prefill,
)
