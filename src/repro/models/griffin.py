"""RecurrentGemma / Griffin recurrent block: RG-LRU + temporal conv
(arXiv:2402.19427). Training/prefill uses an associative scan (log-depth on
TPU); decode keeps an O(1) recurrent state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Array = jnp.ndarray

_LRU_C = 8.0  # the paper's fixed exponent scale


def init_rglru(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_in_gate": (jax.random.normal(ks[0], (d, w)) * d ** -0.5).astype(dtype),
        "w_in_branch": (jax.random.normal(ks[1], (d, w)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": (jax.random.normal(ks[3], (w, w)) * w ** -0.5).astype(dtype),
        "b_a": jnp.zeros((w,), dtype),
        "w_x": (jax.random.normal(ks[4], (w, w)) * w ** -0.5).astype(dtype),
        "b_x": jnp.zeros((w,), dtype),
        "lam": (jnp.ones((w,)) * 2.0).astype(dtype),  # softplus(2) ≈ 2.1 -> slow decay
        "w_out": (jax.random.normal(ks[5], (w, d)) * w ** -0.5).astype(dtype),
    }


def _conv1d(seq: Array, w: Array, b: Array) -> Array:
    K = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i:i + seq.shape[1], :] * w[i] for i in range(K)) + b


def _rglru_coeffs(p: dict, u: Array) -> tuple[Array, Array]:
    """Per-step decay a_t and input b_t for h_t = a_t h_{t-1} + b_t."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_a"]).astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_x"]).astype(jnp.float32)
                       + p["b_x"].astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gate = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = gate * (i * u.astype(jnp.float32))
    return a, b


def rglru_block(p: dict, cfg: ModelConfig, x: Array) -> Array:
    """Full-sequence Griffin recurrent block. x: (B, S, d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_in_gate"]))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in_branch"])
    u = _conv1d(u, p["conv_w"], p["conv_b"])
    a, b = _rglru_coeffs(p, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    return jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"])


class LRUCache(NamedTuple):
    conv: Array  # (B, conv_width-1, w)
    h: Array     # (B, w) float32


def init_lru_cache(cfg: ModelConfig, batch: int, dtype) -> LRUCache:
    w = cfg.lru_width or cfg.d_model
    return LRUCache(conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
                    h=jnp.zeros((batch, w), jnp.float32))


def rglru_decode(p: dict, cfg: ModelConfig, x: Array, cache: LRUCache
                 ) -> tuple[Array, LRUCache]:
    """Single-token decode. x: (B, 1, d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_in_gate"]))[:, 0]
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in_branch"])[:, 0]      # (B, w)
    hist = jnp.concatenate([cache.conv, u[:, None]], axis=1)
    u = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    a, b = _rglru_coeffs(p, u)
    h = a * cache.h + b
    out = jnp.einsum("bw,wd->bd", h.astype(x.dtype) * gate, p["w_out"])[:, None]
    return out, LRUCache(conv=hist[:, 1:], h=h)
