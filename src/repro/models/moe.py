"""Mixture-of-Experts block (Qwen-MoE / Kimi-K2 style).

Top-k routing with shared experts. Dispatch uses the sort-based
capacity-buffer formulation: token-expert assignments are sorted by expert id
and scattered into per-expert capacity buffers, so the expert matmuls are
dense batched einsums over (E, C, d) with the *active* FLOP count
(≈ tokens · top_k · capacity_factor of expert compute, not E×) — this is the
TPU-native dispatch; sharding the expert axis over `model` turns the scatter
into the expert-parallel all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

try:  # jax >= 0.4.35 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # this container's 0.4.37 ships it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map

from .config import ModelConfig
from .layers import init_mlp, mlp

Array = jnp.ndarray


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, E, h = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * d ** -0.5).astype(dtype),
        "wg": (jax.random.normal(ks[1], (E, d, h)) * d ** -0.5).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, h)) * d ** -0.5).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, h, d)) * h ** -0.5).astype(dtype),
    }
    if cfg.n_shared > 0:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared * h, dtype)
    return p


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, min(cap, n_tokens))


def _dispatch(xt: Array, expert_idx: Array, gate: Array, E: int, C: int):
    """Sort-based capacity dispatch. xt: (T, d) -> buf (E, C, d) plus the
    (token, gate, slot) indices needed for the combine."""
    T, d = xt.shape
    K = expert_idx.shape[1]
    flat_expert = expert_idx.reshape(-1)                   # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate.reshape(-1)

    order = jnp.argsort(flat_expert)                       # stable sort by expert
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # rank of each assignment within its expert: position minus the first
    # occurrence of that expert in the sorted array (no (N, E) blow-up)
    rank = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    keep = rank < C
    slot = se * C + jnp.where(keep, rank, 0)

    buf = jnp.zeros((E * C, d), xt.dtype)
    vals = jnp.where(keep[:, None], xt[st], 0.0)
    buf = buf.at[slot].add(vals)                           # scatter (unique slots)
    return buf.reshape(E, C, d), st, jnp.where(keep, sg, 0.0), slot


def _combine(eo: Array, st: Array, sg: Array, slot: Array, T: int) -> Array:
    """Inverse of _dispatch: gather expert outputs back to token order."""
    E, C, d = eo.shape
    gathered = eo.reshape(E * C, d)[slot] * sg[:, None]
    return jnp.zeros((T, d), eo.dtype).at[st].add(gathered)


def moe_block(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Two dispatch modes:
      dense      — single global sort/scatter; correct everywhere, but under
                   SPMD the (E, C, d) capacity buffer is replicated and the
                   scatter-adds are all-reduced across the data axis
                   (~150 GB/layer at kimi scale).
      sharded    — shard_map over the data axes: each data shard sorts its own
                   tokens into a LOCAL capacity slice, so the global buffer is
                   C-sharded and the only cross-shard movement is the
                   expert-parallel all-to-all XLA inserts for the (E@model)
                   einsums. Requires a mesh (repro.dist.context); falls back
                   to dense otherwise. §Perf hillclimb 2, iteration 2.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, K)             # (T, K)
    gate = (gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)).astype(x.dtype)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(density * router_mean)

    from repro.dist.context import current_mesh
    mesh = current_mesh()
    sharded = (cfg.moe_dispatch == "sharded" and mesh is not None
               and "data" in mesh.axis_names)

    if not sharded:
        C = expert_capacity(cfg, T)
        buf, st, sg, slot = _dispatch(xt, expert_idx, gate, E, C)
        g = jnp.einsum("ecd,edh->ech", buf, p["wg"])
        u = jnp.einsum("ecd,edh->ech", buf, p["wu"])
        eo = jnp.einsum("ech,ehd->ecd", jax.nn.silu(g) * u, p["wd"])
        out = _combine(eo, st, sg, slot, T)
    else:
        from jax.sharding import PartitionSpec as P
        dpax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n_dp = 1
        for a in dpax:
            n_dp *= mesh.shape[a]
        assert T % n_dp == 0, (T, n_dp)
        T_l = T // n_dp
        C_l = max(8, int(T_l * K * cfg.capacity_factor / E) + 1)

        def dispatch_local(xt_l, idx_l, gate_l):
            return _dispatch(xt_l, idx_l, gate_l, E, C_l)

        buf, st, sg, slot = _shard_map(
            dispatch_local, mesh=mesh,
            in_specs=(P(dpax, None), P(dpax, None), P(dpax, None)),
            out_specs=(P(None, dpax, None), P(dpax), P(dpax), P(dpax)),
        )(xt, expert_idx, gate)
        # Pin the capacity buffer to the 2-D (expert@model, capacity@data)
        # layout: the single reshard below IS the expert-parallel all-to-all
        # (~tokens·top_k·d bytes per device); without the constraint XLA
        # replicates the buffer and all-reduces it (§Perf hillclimb 2, iter 3).
        from jax.sharding import NamedSharding
        ep_ok = (cfg.moe_shard == "ep" and E % mesh.shape["model"] == 0)
        espec = "model" if ep_ok else None
        buf = jax.lax.with_sharding_constraint(
            buf, NamedSharding(mesh, P(espec, dpax, None)))
        g = jnp.einsum("ecd,edh->ech", buf, p["wg"])
        u = jnp.einsum("ecd,edh->ech", buf, p["wu"])
        eo = jnp.einsum("ech,ehd->ecd", jax.nn.silu(g) * u, p["wd"])
        eo = jax.lax.with_sharding_constraint(
            eo, NamedSharding(mesh, P(espec, dpax, None)))

        def combine_local(eo_l, st_l, sg_l, slot_l):
            return _combine(eo_l, st_l, sg_l, slot_l, T_l)

        out = _shard_map(
            combine_local, mesh=mesh,
            in_specs=(P(None, dpax, None), P(dpax), P(dpax), P(dpax)),
            out_specs=P(dpax, None),
        )(eo, st, sg, slot)

    if cfg.n_shared > 0:
        out = out + mlp(p["shared"], x).reshape(T, d)
    return out.reshape(B, S, d), aux
