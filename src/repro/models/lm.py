"""Language-model assembly: embeddings → (scanned) layer groups → logits.

Layers are organized as  [prefix (unrolled)] + [n_full groups (lax.scan)] +
[remainder (unrolled)]  where one group = the architecture's repeating
pattern (e.g. gemma3's 5 local + 1 global, recurrentgemma's rec,rec,attn).
Scanning groups keeps compile time flat in depth; `cfg.remat` wraps each
group in jax.checkpoint (activation recomputation).

Three execution modes per layer kind:
  forward        — full-sequence training/eval
  prefill        — forward + emit decode cache
  decode         — single token with cache

Modality frontends (audio frames / vision patches) are stubs per the
assignment carve-out: batches carry precomputed embeddings of width d_model.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .griffin import init_lru_cache, init_rglru, rglru_block, rglru_decode
from .layers import attention, attention_decode, attention_decode_paged, init_attention, init_mlp, make_mask, mlp, rms_norm, rope_angles, apply_rope, _qkv, _sdpa
from .moe import init_moe, moe_block
from .ssm import init_ssm, init_ssm_cache, ssm_block, ssm_decode

Array = jnp.ndarray
Pytree = Any


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> tuple[list[str], int, list[str]]:
    """Returns (prefix_kinds, n_full_groups, remainder_kinds)."""
    kinds = list(cfg.layer_kinds())
    g = len(cfg.pattern)
    if not cfg.scan_layers or g >= len(kinds):
        return kinds, 0, []
    n_full = len(kinds) // g
    rem = kinds[n_full * g:]
    return [], n_full, rem


def _mlp_kind(cfg: ModelConfig, kind: str) -> Optional[str]:
    if kind == "ssm":
        return None  # Mamba-2 blocks have no separate MLP
    if cfg.arch_type == "moe":
        return "moe"
    return "dense"


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "ssm":
        p["mix"] = init_ssm(k1, cfg, dtype)
        return p
    if kind == "rec":
        p["mix"] = init_rglru(k1, cfg, dtype)
    else:
        p["mix"] = init_attention(k1, cfg, dtype)
    p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    if _mlp_kind(cfg, kind) == "moe":
        p["mlp"] = init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_lm(key, cfg: ModelConfig) -> Pytree:
    dtype = jnp.dtype(cfg.dtype)
    prefix, n_full, rem = layer_plan(cfg)
    kE, kP, kG, kR, kU = jax.random.split(key, 5)
    params: dict = {
        "embed": (jax.random.normal(kE, (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(kU, (cfg.d_model, cfg.vocab))
                             * cfg.d_model ** -0.5).astype(dtype)
    if prefix:
        params["prefix"] = [
            _init_layer(k, cfg, kind, dtype)
            for k, kind in zip(jax.random.split(kP, len(prefix)), prefix)]
    if n_full:
        def one_group(k):
            return [
                _init_layer(kk, cfg, kind, dtype)
                for kk, kind in zip(jax.random.split(k, len(cfg.pattern)), cfg.pattern)]
        params["groups"] = jax.vmap(one_group)(jax.random.split(kG, n_full))
    if rem:
        params["rem"] = [
            _init_layer(k, cfg, kind, dtype)
            for k, kind in zip(jax.random.split(kR, len(rem)), rem)]
    return params


# ---------------------------------------------------------------------------
# Single-layer forward (three modes)
# ---------------------------------------------------------------------------

def _layer_fwd(lp: dict, cfg: ModelConfig, kind: str, x: Array) -> tuple[Array, Array]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "ssm":
        return x + ssm_block(lp["mix"], cfg, h), jnp.zeros((), jnp.float32)
    if kind == "rec":
        x = x + rglru_block(lp["mix"], cfg, h)
    else:
        x = x + attention(lp["mix"], cfg, h, kind)
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if _mlp_kind(cfg, kind) == "moe":
        y, aux = moe_block(lp["mlp"], cfg, h2)
    else:
        y = mlp(lp["mlp"], h2)
    return x + y, aux


def _attn_prefill(lp: dict, cfg: ModelConfig, kind: str, x: Array, cache_len: int,
                  lens: Optional[Array] = None
                  ) -> tuple[Array, tuple[Array, Array]]:
    """Attention forward that also emits the (ring-layout) KV cache.

    With ``lens`` ((B,) int32 true lengths, right-padded batch) the cache
    write is an exact per-request scatter: only positions < lens[b] (and,
    for local layers, within the trailing window) are written; padded
    positions are dropped, so the emitted cache rows are bit-identical to an
    unpadded prefill (causality keeps the forward itself exact)."""
    B, S, _ = x.shape
    q, k, v = _qkv(lp, cfg, x)
    pos = jnp.arange(S)
    cos, sin = rope_angles(pos, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    mask = make_mask(cfg, S, kind)
    out = _sdpa(cfg, q, k, v, mask)
    out = jnp.einsum("bsh,hd->bsd", out, lp["wo"])
    W = cache_len
    kc = jnp.zeros((B, W, cfg.n_kv, cfg.hd), k.dtype)
    vc = jnp.zeros((B, W, cfg.n_kv, cfg.hd), v.dtype)
    if lens is not None:
        pos_idx = pos[None, :]                               # (1, S)
        if kind == "local":
            tgt = pos_idx % W
            valid = (pos_idx < lens[:, None]) & (pos_idx >= lens[:, None] - W)
        else:
            tgt = jnp.minimum(pos_idx, W - 1)
            valid = pos_idx < lens[:, None]
        tgt = jnp.broadcast_to(jnp.where(valid, tgt, W), (B, S))  # W → dropped
        rows = jnp.arange(B)[:, None]
        kc = kc.at[rows, tgt].set(k, mode="drop")
        vc = vc.at[rows, tgt].set(v, mode="drop")
    elif kind == "local":
        take = min(W, S)
        src_pos = jnp.arange(S - take, S)
        kc = kc.at[:, src_pos % W].set(k[:, -take:])
        vc = vc.at[:, src_pos % W].set(v[:, -take:])
    else:
        take = min(W, S)
        kc = kc.at[:, :take].set(k[:, :take])
        vc = vc.at[:, :take].set(v[:, :take])
    return out, (kc, vc)


def _layer_prefill(lp, cfg, kind, x, cache_len, lens=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "ssm":
        out, cache = _ssm_prefill(lp["mix"], cfg, h, lens)
        return x + out, cache
    if kind == "rec":
        out, cache = _rec_prefill(lp["mix"], cfg, h, lens)
        x = x + out
    else:
        W = cfg.window if kind == "local" else cache_len
        out, cache = _attn_prefill(lp["mix"], cfg, kind, h, W, lens)
        x = x + out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if _mlp_kind(cfg, kind) == "moe":
        y, _ = moe_block(lp["mlp"], cfg, h2)
    else:
        y = mlp(lp["mlp"], h2)
    return x + y, cache


def _conv_window(conv_in: Array, lens: Array, Kw: int) -> Array:
    """Per-request trailing conv window: rows [lens-Kw+1, lens) of ``conv_in``,
    zero-filled where the window reaches before position 0 (matching the
    zero-initialised decode conv cache)."""
    B, S, _ = conv_in.shape
    offs = lens[:, None] - (Kw - 1) + jnp.arange(Kw - 1)[None, :]   # (B, Kw-1)
    g = conv_in[jnp.arange(B)[:, None], jnp.clip(offs, 0, S - 1)]
    return jnp.where((offs >= 0)[..., None], g, 0).astype(conv_in.dtype)


def _ssm_prefill(p, cfg, x, lens=None):
    """Run ssm_block while capturing the final recurrent + conv state.

    With ``lens`` the padded positions get dt = 0 — decay exp(0·A) = 1 and
    update x·dt = 0 — so the emitted state is exactly the state after the
    request's true last token; the conv cache is gathered per request."""
    from .ssm import SSMCache, _conv1d  # local import to reuse internals
    B_, S, _ = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    zxbcdt = jnp.einsum("bsd,do->bso", x, p["in_proj"])
    z, xc, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    Kw = cfg.conv_width
    if lens is None:
        conv_cache = jnp.zeros((B_, Kw - 1, di + 2 * N), x.dtype)
        take = min(Kw - 1, S)
        conv_cache = conv_cache.at[:, Kw - 1 - take:].set(conv_in[:, S - take:])
    else:
        conv_cache = _conv_window(conv_in, lens, Kw)
    conv_out = jax.nn.silu(_conv1d(conv_in, p["conv_w"], p["conv_b"]))
    xc, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if lens is not None:
        pmask = jnp.arange(S)[None, :, None] < lens[:, None, None]
        dt = jnp.where(pmask, dt, 0.0)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xc.reshape(B_, S, H, P)
    y, final_state = ssm_chunked_pad(xh.astype(jnp.float32), dt, A,
                                     Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                                     cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, SSMCache(conv=conv_cache, state=final_state)


def ssm_chunked_pad(x, dt, A, Bm, Cm, chunk, init_state=None):
    """ssd_chunked that right-pads the sequence to a chunk multiple.

    The pad positions carry dt = 0 (decay exp(0·A) = 1, update x·dt = 0), so
    the returned final state is the state after the last REAL position;
    ``init_state`` ((B, H, P, N) f32) seeds the recurrence for chunked
    prefill continuation (None -> zeros)."""
    from .ssm import ssd_chunked
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state=init_state)
    return y[:, :s], state


def _rec_prefill(p, cfg, x, lens=None):
    from .griffin import LRUCache, _conv1d, _rglru_coeffs
    B_, S, _ = x.shape
    w = cfg.lru_width or cfg.d_model
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_in_gate"]))
    u0 = jnp.einsum("bsd,dw->bsw", x, p["w_in_branch"])
    Kw = cfg.conv_width
    if lens is None:
        conv_cache = jnp.zeros((B_, Kw - 1, w), x.dtype)
        take = min(Kw - 1, S)
        conv_cache = conv_cache.at[:, Kw - 1 - take:].set(u0[:, S - take:])
    else:
        conv_cache = _conv_window(u0, lens, Kw)
    u = _conv1d(u0, p["conv_w"], p["conv_b"])
    a, b = _rglru_coeffs(p, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = jnp.einsum("bsw,wd->bsd", h.astype(x.dtype) * gate, p["w_out"])
    # per-request final state: the scan is causal, so h[b, lens[b]-1] is
    # untouched by the right padding
    h_last = h[:, -1] if lens is None else h[jnp.arange(B_), lens - 1]
    return out, LRUCache(conv=conv_cache, h=h_last)


def _layer_decode(lp, cfg, kind, x, cache, pos, page_table=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "ssm":
        out, cache = ssm_decode(lp["mix"], cfg, h, cache)
        return x + out, cache
    if kind == "rec":
        out, cache = rglru_decode(lp["mix"], cfg, h, cache)
        x = x + out
    else:
        kc, vc = cache
        if page_table is not None and kind != "local":
            # paged serve path: kc/vc are page pools, not per-slot rows
            out, kc, vc = attention_decode_paged(lp["mix"], cfg, h, kc, vc,
                                                 page_table, pos)
        else:
            out, kc, vc = attention_decode(lp["mix"], cfg, h, kind, kc, vc, pos)
        cache = (kc, vc)
        x = x + out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if _mlp_kind(cfg, kind) == "moe":
        y, _ = moe_block(lp["mlp"], cfg, h2)
    else:
        y = mlp(lp["mlp"], h2)
    return x + y, cache


# ---------------------------------------------------------------------------
# Chunked serve forward (unified ragged step — prefill chunks + decode rows)
# ---------------------------------------------------------------------------

class ChunkCtx(NamedTuple):
    """Per-row geometry of one ragged chunk batch (see :func:`chunk_step`)."""
    slots: Array      # (Rn,) int32 target slot per row; n_slots = dump (dropped)
    sl: Array         # (Rn,) int32 clamped slot index (safe for gathers)
    fresh: Array      # (Rn,) bool — row starts at absolute position 0
    pos0: Array       # (Rn,) int32 absolute position of the row's first token
    positions: Array  # (Rn, C) int32 absolute position per token
    valid: Array      # (Rn, C) bool — token t real iff t < lens[row]
    lens: Array       # (Rn,) int32 true token count per row


def _attn_chunk(lp: dict, cfg: ModelConfig, kind: str, x: Array, kvc,
                ctx: ChunkCtx, page_table: Optional[Array]):
    """Attention over one ragged chunk batch with per-slot cache carry.

    Every row attends its own causal prefix: the chunk's keys plus whatever
    the slot's cache already holds. Cache writes are drop-scatters keyed by
    ``ctx.slots`` (the dump row n_slots vanishes), so padding rows and
    padding tokens never touch live slots; gathers go through the clamped
    ``ctx.sl`` and are garbage-but-finite for dump rows."""
    Rn, C, _ = x.shape
    q, k, v = _qkv(lp, cfg, x)
    cos, sin = rope_angles(ctx.positions, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    rows = jnp.arange(Rn)[:, None]
    if page_table is not None and kind != "local":
        k_pool, v_pool = kvc
        P = k_pool.shape[-3]
        pps = page_table.shape[1]
        dump = k_pool.shape[0] - 1
        trow = page_table[ctx.slots]                       # (Rn, pps)
        logical = jnp.minimum(ctx.positions // P, pps - 1)
        phys = jnp.where(ctx.valid, trow[rows, logical], dump)
        off = ctx.positions % P
        k_pool = k_pool.at[phys, off].set(k.astype(k_pool.dtype))
        v_pool = v_pool.at[phys, off].set(v.astype(v_pool.dtype))
        if cfg.use_pallas_decode:
            from repro.kernels.swa import ragged_paged_decode_pallas
            cu = C * jnp.arange(Rn + 1, dtype=jnp.int32)
            out = ragged_paged_decode_pallas(
                q.reshape(Rn * C, cfg.n_heads, cfg.hd), k_pool, v_pool,
                trow, cu, ctx.lens, ctx.pos0 + ctx.lens,
                interpret=cfg.pallas_interpret)
            out = out.reshape(Rn, C, -1).astype(x.dtype)
        else:
            kg = k_pool[trow].reshape(Rn, pps * P, cfg.n_kv, cfg.hd)
            vg = v_pool[trow].reshape(Rn, pps * P, cfg.n_kv, cfg.hd)
            mask = (jnp.arange(pps * P)[None, None, :]
                    <= ctx.positions[:, :, None])
            out = _sdpa(cfg, q, kg, vg, mask[:, None])
        return jnp.einsum("bsh,hd->bsd", out, lp["wo"]), (k_pool, v_pool)
    kc, vc = kvc
    W = kc.shape[1]
    if kind == "local":
        # gather the previous window from the OLD ring (pre-scatter: the
        # chunk's own keys ride in dense, so nothing here may alias them)
        qprev = ctx.pos0[:, None] - W + jnp.arange(W)[None, :]   # (Rn, W)
        kprev = kc[ctx.sl[:, None], qprev % W]
        vprev = vc[ctx.sl[:, None], qprev % W]
        keys = jnp.concatenate([kprev.astype(k.dtype), k], axis=1)
        vals = jnp.concatenate([vprev.astype(v.dtype), v], axis=1)
        kpos = jnp.concatenate([qprev, ctx.positions], axis=1)   # (Rn, W+C)
        kval = jnp.concatenate([qprev >= 0, ctx.valid], axis=1)
        p_ = ctx.positions[:, :, None]
        mask = (kval[:, None, :] & (kpos[:, None, :] <= p_)
                & (kpos[:, None, :] > p_ - W))
        out = _sdpa(cfg, q, keys, vals, mask[:, None])
        # write back ONLY the last min(W, len) valid tokens: their ring
        # targets are distinct, and every older ring entry they do not
        # overwrite still holds the right absolute position
        keep = ctx.valid & (jnp.arange(C)[None, :] >= ctx.lens[:, None] - W)
        tgt = jnp.where(keep, ctx.positions % W, W)
        kc = kc.at[ctx.slots[:, None], tgt].set(k.astype(kc.dtype), mode="drop")
        vc = vc.at[ctx.slots[:, None], tgt].set(v.astype(vc.dtype), mode="drop")
    else:
        tgt = jnp.where(ctx.valid, jnp.minimum(ctx.positions, W - 1), W)
        kc = kc.at[ctx.slots[:, None], tgt].set(k.astype(kc.dtype), mode="drop")
        vc = vc.at[ctx.slots[:, None], tgt].set(v.astype(vc.dtype), mode="drop")
        kg = kc[ctx.sl]                                          # (Rn, W, KV, hd)
        vg = vc[ctx.sl]
        mask = jnp.arange(W)[None, None, :] <= ctx.positions[:, :, None]
        out = _sdpa(cfg, q, kg, vg, mask[:, None])
    return jnp.einsum("bsh,hd->bsd", out, lp["wo"]), (kc, vc)


def _ssm_chunk(p, cfg: ModelConfig, x: Array, cache, ctx: ChunkCtx):
    """ssm_block over one chunk with conv + recurrent state carry.

    The conv history is the previous chunk's trailing ``conv_width - 1``
    inputs (zeros when fresh — matching the decode conv cache init); padded
    tokens get dt = 0, so the emitted state is exactly the state after the
    row's last real token."""
    from .ssm import SSMCache
    Rn, C, _ = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    zxbcdt = jnp.einsum("bsd,do->bso", x, p["in_proj"])
    z, xc, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    Kw = cfg.conv_width
    conv_prev = jnp.where(ctx.fresh[:, None, None], 0,
                          cache.conv[ctx.sl]).astype(conv_in.dtype)
    combined = jnp.concatenate([conv_prev, conv_in], axis=1)  # (Rn, Kw-1+C, ·)
    conv_out = sum(combined[:, i:i + C] * p["conv_w"][i] for i in range(Kw))
    conv_out = jax.nn.silu(conv_out + p["conv_b"])
    rows = jnp.arange(Rn)[:, None]
    # trailing window ending at the row's LAST REAL token (combined index
    # lens + j is that token's conv input at history offset j - (Kw-1))
    new_conv = combined[rows, ctx.lens[:, None] + jnp.arange(Kw - 1)[None, :]]
    xc, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    dt = jnp.where(ctx.valid[..., None], dt, 0.0)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xc.reshape(Rn, C, H, P)
    init = jnp.where(ctx.fresh[:, None, None, None], 0.0, cache.state[ctx.sl])
    y, final_state = ssm_chunked_pad(xh.astype(jnp.float32), dt, A,
                                     Bc.astype(jnp.float32),
                                     Cc.astype(jnp.float32),
                                     cfg.ssm_chunk, init_state=init)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Rn, C, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_cache = SSMCache(
        conv=cache.conv.at[ctx.slots].set(new_conv.astype(cache.conv.dtype),
                                          mode="drop"),
        state=cache.state.at[ctx.slots].set(final_state, mode="drop"))
    return out, new_cache


def _rec_chunk(p, cfg: ModelConfig, x: Array, cache, ctx: ChunkCtx):
    """rglru_block over one chunk with conv + hidden-state carry.

    The associative scan keeps BOTH outputs — the running decay product
    ``a_cum`` and the zero-init hidden ``h0`` — so the carried state enters
    as ``h = h0 + a_cum · h_init`` (affine-map composition), exactly the
    decode recurrence iterated over the chunk."""
    from .griffin import LRUCache, _rglru_coeffs
    Rn, C, _ = x.shape
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_in_gate"]))
    u0 = jnp.einsum("bsd,dw->bsw", x, p["w_in_branch"])
    Kw = cfg.conv_width
    conv_prev = jnp.where(ctx.fresh[:, None, None], 0,
                          cache.conv[ctx.sl]).astype(u0.dtype)
    combined = jnp.concatenate([conv_prev, u0], axis=1)
    u = sum(combined[:, i:i + C] * p["conv_w"][i] for i in range(Kw))
    u = u + p["conv_b"]
    rows = jnp.arange(Rn)[:, None]
    new_conv = combined[rows, ctx.lens[:, None] + jnp.arange(Kw - 1)[None, :]]
    a, b = _rglru_coeffs(p, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_cum, h0 = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_init = jnp.where(ctx.fresh[:, None], 0.0, cache.h[ctx.sl])
    h = h0 + a_cum * h_init[:, None, :]
    out = jnp.einsum("bsw,wd->bsd", h.astype(x.dtype) * gate, p["w_out"])
    h_last = h[jnp.arange(Rn), jnp.maximum(ctx.lens - 1, 0)]
    new_cache = LRUCache(
        conv=cache.conv.at[ctx.slots].set(new_conv.astype(cache.conv.dtype),
                                          mode="drop"),
        h=cache.h.at[ctx.slots].set(h_last, mode="drop"))
    return out, new_cache


def _layer_chunk(lp, cfg, kind, x, cache, ctx, page_table=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "ssm":
        out, cache = _ssm_chunk(lp["mix"], cfg, h, cache, ctx)
        return x + out, cache
    if kind == "rec":
        out, cache = _rec_chunk(lp["mix"], cfg, h, cache, ctx)
        x = x + out
    else:
        out, cache = _attn_chunk(lp["mix"], cfg, kind, h, cache, ctx,
                                 page_table)
        x = x + out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if _mlp_kind(cfg, kind) == "moe":
        y, _ = moe_block(lp["mlp"], cfg, h2)
    else:
        y = mlp(lp["mlp"], h2)
    return x + y, cache


def chunk_step(params: Pytree, cfg: ModelConfig, cache: dict, tokens: Array,
               row_slots: Array, row_lens: Array, row_fresh: Array,
               page_table: Optional[Array] = None) -> tuple[Array, dict]:
    """One unified ragged step over a mixed chunk batch (the serve hot path).

    ``tokens`` (Rn, C) int32 packs prefill CHUNKS and decode rows (C-column
    rows with ``row_lens = 1``) into one call against the slot cache:
    row r appends its ``row_lens[r]`` real tokens to slot ``row_slots[r]``
    (``n_slots`` = dump — the row computes garbage and writes nothing),
    starting at position 0 when ``row_fresh[r]`` else at the slot's current
    ``cache["pos"]``. All mixers carry per-slot chunk state exactly: KV
    scatter (dense rows or block-table pages), local ring window carry, SSM
    conv + recurrent init_state, RG-LRU conv + affine hidden carry. Returns
    (logits (Rn, 1, V) at each row's LAST real token, updated cache) —
    callers jit with ``donate_argnums`` on the cache. Requires a causal
    text-frontend model; padding tokens stay finite but their values are
    never read back."""
    assert cfg.causal and cfg.frontend == "none", \
        "chunked serving requires a causal token-frontend model"
    dtype = jnp.dtype(cfg.dtype)
    pos = cache["pos"]                                   # (S,) int32
    S = pos.shape[0]
    Rn, C = tokens.shape
    row_slots = jnp.asarray(row_slots, jnp.int32)
    row_lens = jnp.asarray(row_lens, jnp.int32)
    row_fresh = jnp.asarray(row_fresh, bool)
    sl = jnp.minimum(row_slots, S - 1)
    pos0 = jnp.where(row_fresh, 0, pos[sl])
    positions = pos0[:, None] + jnp.arange(C)[None, :]
    valid = jnp.arange(C)[None, :] < row_lens[:, None]
    ctx = ChunkCtx(slots=row_slots, sl=sl, fresh=row_fresh, pos0=pos0,
                   positions=positions, valid=valid, lens=row_lens)
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, dtype)
    prefix, n_full, rem = layer_plan(cfg)
    new_cache: dict = {"pos": pos.at[row_slots].set(pos0 + row_lens,
                                                    mode="drop")}

    if prefix:
        cps = []
        for lp, kind, cp in zip(params["prefix"], prefix, cache["prefix"]):
            x, cp = _layer_chunk(lp, cfg, kind, x, cp, ctx, page_table)
            cps.append(cp)
        new_cache["prefix"] = cps

    if n_full:
        def group_body(x, gp_cache):
            gp, gc = gp_cache
            cs = []
            for lp, kind, cp in zip(gp, cfg.pattern, gc):
                x, cp = _layer_chunk(lp, cfg, kind, x, cp, ctx, page_table)
                cs.append(cp)
            return x, tuple(cs)
        x, gcache = jax.lax.scan(group_body, x,
                                 (params["groups"], tuple(cache["groups"])))
        new_cache["groups"] = list(gcache)

    if rem:
        crs = []
        for lp, kind, cp in zip(params["rem"], rem, cache["rem"]):
            x, cp = _layer_chunk(lp, cfg, kind, x, cp, ctx, page_table)
            crs.append(cp)
        new_cache["rem"] = crs

    x = x[jnp.arange(Rn), jnp.maximum(row_lens - 1, 0)][:, None]  # (Rn, 1, d)
    return logits_from_hidden(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params: Pytree, cfg: ModelConfig, batch: dict) -> Array:
    dtype = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        return batch["frames"].astype(dtype)
    tok = params["embed"][batch["tokens"]] * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if cfg.frontend == "vision":
        return jnp.concatenate([batch["patches"].astype(dtype), tok], axis=1)
    return tok


def logits_from_hidden(params: Pytree, cfg: ModelConfig, x: Array) -> Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"])


# ---------------------------------------------------------------------------
# Full forward / loss
# ---------------------------------------------------------------------------

def forward(params: Pytree, cfg: ModelConfig, batch: dict) -> tuple[Array, Array]:
    """Returns (logits, aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    prefix, n_full, rem = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    layer_fwd = (jax.checkpoint(_layer_fwd, static_argnums=(1, 2))
                 if cfg.remat else _layer_fwd)

    for lp, kind in zip(params.get("prefix", []), prefix):
        x, aux = layer_fwd(lp, cfg, kind, x)
        aux_total = aux_total + aux

    if n_full:
        def group_body(x, gp):
            a = jnp.zeros((), jnp.float32)
            for lp, kind in zip(gp, cfg.pattern):
                x, ax = _layer_fwd(lp, cfg, kind, x)
                a = a + ax
            return x, a
        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        x, auxs = jax.lax.scan(group_body, x, params["groups"])
        aux_total = aux_total + jnp.sum(auxs)

    for lp, kind in zip(params.get("rem", []), rem):
        x, aux = layer_fwd(lp, cfg, kind, x)
        aux_total = aux_total + aux

    if cfg.frontend == "vision":
        x = x[:, -batch["tokens"].shape[1]:]  # logits over text positions only
    return logits_from_hidden(params, cfg, x), aux_total


def lm_loss(params: Pytree, cfg: ModelConfig, batch: dict) -> Array:
    """Next-token (or frame-label) cross entropy, mean over valid positions."""
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    valid = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0) + aux


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------

def _kind_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    if kind == "rec":
        return init_lru_cache(cfg, batch, dtype)
    W = cfg.window if kind == "local" else max_len
    kc = jnp.zeros((batch, W, cfg.n_kv, cfg.hd), dtype)
    return (kc, kc)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    prefix, n_full, rem = layer_plan(cfg)
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if prefix:
        cache["prefix"] = [_kind_cache(cfg, k, batch, max_len, dtype) for k in prefix]
    if n_full:
        one = [_kind_cache(cfg, k, batch, max_len, dtype) for k in cfg.pattern]
        cache["groups"] = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (n_full,) + l.shape).copy(), one)
    if rem:
        cache["rem"] = [_kind_cache(cfg, k, batch, max_len, dtype) for k in rem]
    return cache


def prefill(params: Pytree, cfg: ModelConfig, batch: dict, max_len: int,
            lens: Optional[Array] = None) -> tuple[Array, dict]:
    """Full forward over the prompt, emitting logits and the decode cache.

    ``lens`` ((B,) int32) enables exact right-padded prefill for the serve
    path: each request's true sequence length (vision: patches + text). The
    emitted per-request cache rows — KV scatter, SSM state (dt-masked),
    RG-LRU state — match an unpadded prefill of that request exactly, and
    ``cache["pos"]`` is the per-slot (B,) position vector that
    ``decode_step`` advances independently. Logits are returned ONLY at each
    request's last real position — shape (B, 1, V), the hidden row is
    gathered BEFORE the unembed so the (B, S, V) matmul never materializes
    on the serving hot path. Requires a causal model."""
    if lens is not None:
        assert cfg.causal, "right-padded exact prefill requires a causal model"
    x = embed_inputs(params, cfg, batch)
    S = x.shape[1]
    prefix, n_full, rem = layer_plan(cfg)
    cache: dict = {}

    if prefix:
        cps = []
        for lp, kind in zip(params["prefix"], prefix):
            x, cp = _layer_prefill(lp, cfg, kind, x, max_len, lens)
            cps.append(cp)
        cache["prefix"] = cps

    if n_full:
        def group_body(x, gp):
            cs = []
            for lp, kind in zip(gp, cfg.pattern):
                x, cp = _layer_prefill(lp, cfg, kind, x, max_len, lens)
                cs.append(cp)
            return x, tuple(cs)
        x, gcache = jax.lax.scan(group_body, x, params["groups"])
        cache["groups"] = list(gcache)

    if rem:
        crs = []
        for lp, kind in zip(params["rem"], rem):
            x, cp = _layer_prefill(lp, cfg, kind, x, max_len, lens)
            crs.append(cp)
        cache["rem"] = crs

    cache["pos"] = (jnp.asarray(S, jnp.int32) if lens is None
                    else lens.astype(jnp.int32))
    if cfg.frontend == "vision":
        x = x[:, -batch["tokens"].shape[1]:]
    if lens is not None:
        idx = lens - 1
        if cfg.frontend == "vision":
            idx = idx - cfg.n_patches        # x is text-relative here
        x = x[jnp.arange(x.shape[0]), idx][:, None]   # (B, 1, d)
    return logits_from_hidden(params, cfg, x), cache


def decode_step(params: Pytree, cfg: ModelConfig, cache: dict, tokens: Array,
                page_table: Optional[Array] = None) -> tuple[Array, dict]:
    """One decode step. tokens: (B, 1) int32. Returns (logits (B,1,V), cache).

    ``cache["pos"]`` may be a scalar (one shared depth — the classic batched
    path) or a (B,) vector (slot-mapped serving: every row decodes at its own
    absolute position; see repro.serve). With ``page_table`` ((≥B,
    pages_per_slot) int32) the cache is the PAGED serve layout: global/full
    attention leaves are block-table page pools (serve/cache.py
    ``init_paged_cache``) and each slot's KV is gathered through its table
    row; local ring, SSM and RG-LRU leaves stay per-slot."""
    dtype = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, dtype)
    prefix, n_full, rem = layer_plan(cfg)
    new_cache: dict = {"pos": pos + 1}

    if prefix:
        cps = []
        for lp, kind, cp in zip(params["prefix"], prefix, cache["prefix"]):
            x, cp = _layer_decode(lp, cfg, kind, x, cp, pos, page_table)
            cps.append(cp)
        new_cache["prefix"] = cps

    if n_full:
        def group_body(x, gp_cache):
            gp, gc = gp_cache
            cs = []
            for lp, kind, cp in zip(gp, cfg.pattern, gc):
                x, cp = _layer_decode(lp, cfg, kind, x, cp, pos, page_table)
                cs.append(cp)
            return x, tuple(cs)
        x, gcache = jax.lax.scan(group_body, x, (params["groups"], tuple(cache["groups"])))
        new_cache["groups"] = list(gcache)

    if rem:
        crs = []
        for lp, kind, cp in zip(params["rem"], rem, cache["rem"]):
            x, cp = _layer_decode(lp, cfg, kind, x, cp, pos, page_table)
            crs.append(cp)
        new_cache["rem"] = crs

    return logits_from_hidden(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# Analytic parameter count (for config validation tests)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.hd
    n = cfg.vocab * d  # embed
    if not cfg.tie_embeddings:
        n += d * cfg.vocab
    n += d  # final norm
    for kind in cfg.layer_kinds():
        n += d  # ln1
        if kind == "ssm":
            di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
            n += d * (2 * di + 2 * N + H)           # in_proj
            n += cfg.conv_width * (di + 2 * N) + (di + 2 * N)
            n += 3 * H + di + di * d                # a_log, dt_bias, d_skip, norm, out
            continue
        if kind == "rec":
            w = cfg.lru_width or d
            n += 2 * d * w + cfg.conv_width * w + w
            n += 2 * (w * w + w) + w + w * d
        else:
            n += d * cfg.n_heads * hd + 2 * d * cfg.n_kv * hd + cfg.n_heads * hd * d
            if cfg.qkv_bias:
                n += cfg.n_heads * hd + 2 * cfg.n_kv * hd
            if cfg.qk_norm:
                n += 2 * hd
        n += d  # ln2
        if _mlp_kind(cfg, kind) == "moe":
            n += d * cfg.n_experts
            n += cfg.n_experts * (2 * d * cfg.d_expert + cfg.d_expert * d)
            if cfg.n_shared:
                n += 3 * d * cfg.n_shared * cfg.d_expert
        else:
            n += 3 * d * cfg.d_ff
    return n
