"""Adaptive Byzantine attackers — tuned against the RESOLVED aggregator.

Static attacks (Appendix D) are the weak form of the threat model: ``little``
picks its deviation ``z`` from worker masses alone and ``empire`` fixes its
scale a priori, so a defense evaluated only against them can look far more
robust than it is (the Zeno++ observation). The attackers here close that
gap INSIDE the jitted step: they see the same momentum buffers and weights
the omniscient static attacks see, plus the actual aggregation rule the
server resolved, and optimize their transmitted vector against it.

    adaptive_scale  golden-section + grid search over the little/empire
                    scale ``z``: candidates ``μ - z·σ`` (little family) and
                    ``-z·μ`` (empire family), scored by how far the
                    AGGREGATED update is pushed against the honest descent
                    direction; the best family's bracket is then refined by
                    golden-section — all under vmap, no recompiles.
    adaptive_grad   gradient-THROUGH-the-aggregator ascent: a few normalized
                    gradient steps on the same damage objective, starting
                    from the empire vector. Exact for smooth rules (ω-GM's
                    Weiszfeld iterations); for sort-based rules (ω-CWMed) the
                    a.e.-zero gradient makes it degrade toward its empire
                    init — which is precisely the robustness story the matrix
                    is meant to surface.

Both reuse :func:`repro.core.attacks.weighted_honest_stats` (the same
weighted coordinate-wise statistics the static omniscient attacks use) and
plug into the engine's ``attack_fn`` seam with the
``(D, honest_mask, weights, own_update)`` signature, so they run unchanged
in the sequential engine and vmapped across a fleet scenario batch.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.attacks import (ATTACKS, AttackConfig, byzantine_vector,
                                weighted_honest_stats)

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map

ADAPTIVE_ATTACKS = ("adaptive_scale", "adaptive_grad")
#: Every attack name a fleet Scenario accepts.
FLEET_ATTACKS = tuple(a for a in ATTACKS if a != "none") + ADAPTIVE_ATTACKS

_GOLDEN = 0.6180339887498949  # (√5 − 1)/2


def _vdot(a: Pytree, b: Pytree) -> Array:
    return sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _norm(a: Pytree) -> Array:
    return jnp.sqrt(jnp.maximum(_vdot(a, a), 1e-30))


def _with_byz_rows(D: Pytree, honest_mask: Array, v: Pytree) -> Pytree:
    """Every Byzantine row of the stacked buffers replaced by ``v``."""
    def put(l, vl):
        mask = honest_mask.reshape((-1,) + (1,) * (vl.ndim))
        return jnp.where(mask, l, vl[None].astype(l.dtype))

    return _tmap(put, D, v)


def damage(agg_fn: Callable, D: Pytree, honest_mask: Array, weights: Array,
           mu_hat: Pytree, v: Pytree) -> Array:
    """The attacker's objective: how strongly the aggregate points AGAINST
    the honest descent direction once every Byzantine row transmits ``v``.
    The server applies ``w ← w − η·agg(D, s)``, honest progress is along the
    weighted honest mean ``μ``, so maximizing ``−⟨agg(D_v, s), μ̂⟩`` turns
    the server step from descent into ascent as hard as the rule allows."""
    d_hat = agg_fn(_with_byz_rows(D, honest_mask, v), weights)
    return -_vdot(d_hat, mu_hat)


def _golden_refine(f: Callable[[Array], Array], lo: Array, hi: Array,
                   iters: int) -> Array:
    """Golden-section MAXIMIZATION of ``f`` on [lo, hi] with a static
    iteration count — pure arithmetic, safe under jit + vmap."""
    a, b = lo, hi
    c = b - _GOLDEN * (b - a)
    d = a + _GOLDEN * (b - a)
    fc, fd = f(c), f(d)
    for _ in range(iters):
        left = fc > fd
        a = jnp.where(left, a, c)
        b = jnp.where(left, d, b)
        c = b - _GOLDEN * (b - a)
        d = a + _GOLDEN * (b - a)
        fc, fd = f(c), f(d)
    return 0.5 * (a + b)


def adaptive_scale_attack(
    agg_fn: Callable,
    D: Pytree, honest_mask: Array, weights: Array, own_update: Pytree,
    *, z_lo: float = 0.0, z_hi: float = 8.0, n_grid: int = 9,
    gs_iters: int = 6,
) -> Pytree:
    """Grid + golden-section search over the little/empire scale ``z``.

    Evaluates ``v_little(z) = μ − z·σ`` and ``v_empire(z) = −z·μ`` on an
    ``n_grid``-point grid over ``[z_lo, z_hi]``, golden-section-refines each
    family inside the bracket around its grid winner, and transmits the best
    vector overall. Every candidate is scored through the REAL resolved
    ``agg_fn`` — the attack automatically re-tunes when the defense changes.
    """
    mu, sd = weighted_honest_stats(D, honest_mask, weights)
    mu_hat = _tmap(lambda l: l / _norm(mu), mu)

    v_little = lambda z: _tmap(lambda m_, s_: m_ - z * s_, mu, sd)
    v_empire = lambda z: _tmap(lambda m_: -z * m_, mu)
    J = partial(damage, agg_fn, D, honest_mask, weights, mu_hat)

    zs = jnp.linspace(z_lo, z_hi, n_grid)
    half = 0.5 * (z_hi - z_lo) / (n_grid - 1)
    best = []
    for fam in (v_little, v_empire):
        scores = jax.vmap(lambda z: J(fam(z)))(zs)
        z0 = zs[jnp.argmax(scores)]
        z_ref = _golden_refine(lambda z: J(fam(z)),
                               jnp.maximum(z0 - half, z_lo),
                               jnp.minimum(z0 + half, z_hi), gs_iters)
        # keep the refinement only if it actually beat the grid winner
        z_star = jnp.where(J(fam(z_ref)) >= jnp.max(scores), z_ref, z0)
        best.append((fam(z_star), J(fam(z_star))))

    (vl, jl), (ve, je) = best
    return _tmap(lambda a, b: jnp.where(jl >= je, a, b), vl, ve)


def adaptive_grad_attack(
    agg_fn: Callable,
    D: Pytree, honest_mask: Array, weights: Array, own_update: Pytree,
    *, grad_steps: int = 6, step_frac: float = 0.5, clip_mult: float = 8.0,
) -> Pytree:
    """Gradient ascent on the damage objective THROUGH the aggregator.

    Starts at the empire vector ``−μ`` and takes ``grad_steps`` normalized
    ascent steps of size ``step_frac·‖μ‖`` on ``−⟨agg(D_v, s), μ̂⟩``,
    differentiating straight through the resolved rule (Weiszfeld loops
    included); the iterate is kept inside ``clip_mult·‖μ‖`` so unbounded
    directions cannot hide behind the trim."""
    mu, _ = weighted_honest_stats(D, honest_mask, weights)
    mu_norm = _norm(mu)
    mu_hat = _tmap(lambda l: l / mu_norm, mu)
    J = partial(damage, agg_fn, D, honest_mask, weights, mu_hat)
    grad_J = jax.grad(J)

    v = _tmap(jnp.negative, mu)
    for _ in range(grad_steps):
        g = grad_J(v)
        gn = _norm(g)
        step = step_frac * mu_norm
        v = _tmap(lambda vl, gl: vl + step * gl / gn, v, g)
        vn = _norm(v)
        scale = jnp.minimum(1.0, clip_mult * mu_norm / vn)
        v = _tmap(lambda vl: scale * vl, v)
    return v


_ADAPTIVE_BUILDERS: Dict[str, Callable] = {
    "adaptive_scale": adaptive_scale_attack,
    "adaptive_grad": adaptive_grad_attack,
}


def make_attack_fn(name: str, agg_fn: Callable,
                   params: Optional[dict] = None) -> Callable:
    """Build the engine's ``attack_fn(D, honest_mask, weights, own_update)``
    for any fleet attack name — the static Appendix D suite falls through to
    :func:`byzantine_vector`, the adaptive names close over the resolved
    ``agg_fn``. ``params`` carries the attack's static knobs (grid bounds,
    ascent steps, epsilon, …)."""
    params = dict(params or {})
    if name in _ADAPTIVE_BUILDERS:
        return partial(_ADAPTIVE_BUILDERS[name], agg_fn, **params)
    if name not in ATTACKS:
        raise KeyError(f"unknown fleet attack {name!r}; choose from "
                       f"{FLEET_ATTACKS}")
    akw = {k: v for k, v in params.items() if k in AttackConfig._fields}
    return partial(byzantine_vector, AttackConfig(name, **akw))
