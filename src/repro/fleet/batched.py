"""Batched scenario execution: ONE jitted, vmapped Alg. 2 step per group.

A :class:`FleetGroup` takes scenarios sharing a compile signature, stacks
their engine states along a leading scenario axis
(`core.engine.stack_engine_states`), and drives them with a single
``jit(vmap(engine_step))`` — per-scenario arrival probabilities, Byzantine
masks and the weighted-rule ablation flag ride in as traced arguments, so a
group of S scenarios with m workers each advances S·m simulated workers per
device step and the breakdown bisection re-runs with new Byzantine masses
without recompiling. :func:`run_sequential` drives the SAME pure step
unvmapped — the parity reference the tests pin the batched trajectories
against, step for step.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.agg import resolve
from repro.core.engine import (EngineState, arrival_probs, byz_mask_array,
                               engine_init, make_step_fn, stack_engine_states,
                               unstack_engine_state)

from .adaptive import make_attack_fn
from .scenario import (Problem, Scenario, build_problem, compile_signature,
                       engine_config, group_scenarios, resolved_byz_ids)

_tmap = jax.tree_util.tree_map


class FleetResult(NamedTuple):
    """One scenario's outcome: the final engine state row, the problem's
    held-out evaluation (``loss`` always; ``acc``/``excess`` per family),
    the empirical Byzantine-update fraction, and the group-amortized step
    cost."""
    scenario: Scenario
    state: EngineState
    eval: dict
    lambda_emp: float
    us_per_step: float


def _scenario_statics(sc: Scenario):
    """(cfg, probs, mask, weighted) — the per-scenario traced arguments."""
    cfg = engine_config(sc)
    probs = jnp.asarray(arrival_probs(cfg))
    mask = jnp.asarray(byz_mask_array(sc.m, resolved_byz_ids(sc)))
    return cfg, probs, mask, jnp.asarray(sc.weighted)


class FleetGroup:
    """Runs one compile group of scenarios behind a single jitted vmapped
    step. Build via :func:`run_scenarios` unless you need the group handle
    itself (the breakdown bisection does — it re-runs a group with new
    Byzantine masses on the already-compiled step)."""

    def __init__(self, scenarios: List[Scenario],
                 problem: Optional[Problem] = None,
                 collect_metrics: bool = False):
        if not scenarios:
            raise ValueError("FleetGroup needs at least one scenario")
        sigs = {compile_signature(sc) for sc in scenarios}
        if len(sigs) > 1:
            raise ValueError(
                f"scenarios span {len(sigs)} compile signatures — group them "
                f"with repro.fleet.group_scenarios first")
        self.scenarios = list(scenarios)
        rep = scenarios[0]
        self.problem = problem or build_problem(rep)
        self.agg_fn = resolve(rep.agg, lam=rep.lam, backend=rep.agg_backend)
        self.attack_fn = make_attack_fn(rep.attack, self.agg_fn,
                                        dict(rep.attack_params))
        cfg = engine_config(rep)
        self._grad_fn = jax.grad(self.problem.loss_fn)
        # collect_metrics is STATIC and part of the group's single compile:
        # True adds the engine.* telemetry outputs to the vmapped step
        # (still one compile per group), False lowers to today's HLO
        self.collect_metrics = collect_metrics
        step = make_step_fn(cfg, self.problem.loss_fn, agg_fn=self.agg_fn,
                            attack_fn=self.attack_fn, per_worker_batch=True,
                            collect_metrics=collect_metrics)
        self._vstep = jax.jit(jax.vmap(step), donate_argnums=(0,))

    def init(self, scs: List[Scenario]) -> tuple[EngineState, list]:
        """Stacked initial state + one live data stream per scenario (the
        first draw of each stream is consumed as the Alg. 2 line-2 init
        minibatches, exactly like the sequential path)."""
        streams = [self.problem.stream(sc) for sc in scs]
        states = []
        for sc, stream in zip(scs, streams):
            cfg, _, mask, _ = _scenario_statics(sc)
            states.append(engine_init(cfg, self._grad_fn,
                                      self.problem.init_params(sc),
                                      next(stream), mask))
        return stack_engine_states(states), streams

    def run(self, scenarios: Optional[List[Scenario]] = None,
            evaluate: bool = True, obs=None,
            group: int = 0) -> List[FleetResult]:
        """Drive every scenario to ITS OWN step count (the group runs to the
        max and snapshots each scenario's row as it crosses its horizon).

        ``scenarios`` overrides the group's list WITHOUT recompiling — the
        replacements must share the group's compile signature (this is how
        the breakdown bisection sweeps Byzantine mass on one compiled step).

        ``obs`` (a :class:`repro.obs.RunObs`) streams per-step per-scenario
        loss vectors — and, when the group was built with
        ``collect_metrics=True``, the device-collected ``engine.*`` telemetry
        — labelled by ``group`` so a multi-group matrix stays separable."""
        scs = self.scenarios if scenarios is None else list(scenarios)
        sig = compile_signature(self.scenarios[0])
        bad = [sc.label for sc in scs if compile_signature(sc) != sig]
        if bad:
            raise ValueError(f"scenario(s) {bad} do not match this group's "
                             f"compile signature")
        state, streams = self.init(scs)
        probs = jnp.stack([_scenario_statics(sc)[1] for sc in scs])
        masks = jnp.stack([_scenario_statics(sc)[2] for sc in scs])
        weighted = jnp.asarray([sc.weighted for sc in scs])
        max_steps = max(sc.steps for sc in scs)
        if obs is not None:
            obs.event("fleet.group", group=group,
                      scenarios=[sc.label for sc in scs])

        snapshots: Dict[int, EngineState] = {}
        t0 = time.perf_counter()
        for t in range(max_steps):
            batch = _tmap(lambda *ls: jnp.stack(ls),
                          *[next(s) for s in streams])
            state, metrics = self._vstep(state, batch, probs, masks, weighted)
            if obs is not None:
                obs.metric("fleet.loss", metrics["loss"], step=t + 1,
                           group=group)
                if self.collect_metrics:
                    obs.metric_tree({n: v for n, v in metrics.items()
                                     if n.startswith("engine.")},
                                    step=t + 1, group=group)
            for i, sc in enumerate(scs):
                if sc.steps == t + 1:
                    snapshots[i] = unstack_engine_state(state, i)
        jax.block_until_ready(snapshots[max(snapshots)].x)
        us = (time.perf_counter() - t0) / max_steps * 1e6

        out = []
        for i, sc in enumerate(scs):
            row = snapshots[i]
            ev = self.problem.evaluate(row.x, sc) if evaluate else {}
            lam = float(row.t_byz) / max(float(row.t), 1.0)
            out.append(FleetResult(sc, row, ev, lam, us))
        return out


def run_scenarios(scenarios: List[Scenario], obs=None) -> List[FleetResult]:
    """THE fleet runner: group by compile signature, run each group behind
    one jitted vmapped step, scatter results back to input order. ``obs``
    streams per-group loss trajectories (device telemetry too when its
    ``device_metrics`` flag is set) through each group's run."""
    collect = obs is not None and getattr(obs, "device_metrics", False)
    results: List[Optional[FleetResult]] = [None] * len(scenarios)
    for gid, (_, idxs) in enumerate(group_scenarios(scenarios).items()):
        group = FleetGroup([scenarios[i] for i in idxs],
                           collect_metrics=collect)
        for idx, res in zip(idxs, group.run(obs=obs, group=gid)):
            results[idx] = res
    return results  # type: ignore[return-value]


def run_sequential(sc: Scenario, evaluate: bool = True) -> FleetResult:
    """The unbatched reference: the SAME pure step, same data stream, same
    RNG — jitted without the vmap. Exists so tests can pin batched-fleet
    trajectories step-for-step against the sequential engine."""
    problem = build_problem(sc)
    cfg, probs, mask, weighted = _scenario_statics(sc)
    agg_fn = resolve(sc.agg, lam=sc.lam, backend=sc.agg_backend)
    attack_fn = make_attack_fn(sc.attack, agg_fn, dict(sc.attack_params))
    step = jax.jit(make_step_fn(cfg, problem.loss_fn, agg_fn=agg_fn,
                                attack_fn=attack_fn, per_worker_batch=True),
                   donate_argnums=(0,))
    stream = problem.stream(sc)
    state = engine_init(cfg, jax.grad(problem.loss_fn),
                        problem.init_params(sc), next(stream), mask)
    t0 = time.perf_counter()
    for _ in range(sc.steps):
        state, _ = step(state, next(stream), probs, mask, weighted)
    jax.block_until_ready(state.x)
    us = (time.perf_counter() - t0) / max(sc.steps, 1) * 1e6
    ev = problem.evaluate(state.x, sc) if evaluate else {}
    lam = float(state.t_byz) / max(float(state.t), 1.0)
    return FleetResult(sc, state, ev, lam, us)
