"""The robustness matrix: breakdown-point search + per-cell cost accounting.

Each :class:`~repro.fleet.scenario.Scenario` is one cell. For every cell this
module reports

    final_loss / acc      the attacked run at the cell's own Byzantine mass
    honest_loss / acc     the SAME scenario with zero Byzantine workers (runs
                          inside the same compile group — the attack branch
                          no-ops when the Byzantine mask is empty)
    breakdown_count/frac  the smallest Byzantine worker count at which the
                          cell's honest-loss envelope breaks, found by
                          BISECTION over Byzantine mass — every probe reuses
                          the group's already-compiled vmapped step because
                          the Byzantine mask is a traced argument
    agg_us_per_call       the resolved aggregator's standalone cost at the
                          cell's (m, d) shape
    engine_us_per_step    group-amortized wall clock of the full Alg. 2 step

A cell is BROKEN when its eval loss exceeds ``honest_loss · factor + margin``
or goes non-finite. The bisection invariant is [lo known-OK, hi known-broken]
with ``hi = m`` as the virtual always-broken endpoint, so ``breakdown_count``
is the first failing count and ``breakdown_frac = breakdown_count / m`` is
``1.0`` exactly when the rule survived every feasible mass (≤ m − 1).
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.agg import resolve

from .batched import FleetGroup, FleetResult
from .scenario import (Scenario, build_problem, compile_signature,
                       group_scenarios, resolved_byz_ids)


def run_cached(scenarios: List[Scenario],
               cache: Dict[tuple, FleetGroup]) -> List[FleetResult]:
    """`run_scenarios`, but FleetGroups persist in ``cache`` across calls —
    repeated sweeps over a shape class (the bisection) never recompile."""
    results: List[Optional[FleetResult]] = [None] * len(scenarios)
    for sig, idxs in group_scenarios(scenarios).items():
        grp = cache.get(sig)
        if grp is None:
            grp = cache[sig] = FleetGroup([scenarios[i] for i in idxs])
        for idx, res in zip(idxs, grp.run([scenarios[i] for i in idxs])):
            results[idx] = res
    return results  # type: ignore[return-value]


def time_agg_us(spec: str, lam: float, backend: str, m: int, d: int,
                iters: int = 50) -> float:
    """Standalone µs/call of a resolved aggregator at shape (m, d) — the
    Table-1-style cost column of the matrix, measured outside the engine."""
    agg_fn = resolve(spec, lam=lam, backend=backend)
    X = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    s = jnp.arange(1.0, m + 1.0, dtype=jnp.float32)
    f = jax.jit(agg_fn)
    jax.block_until_ready(f(X, s))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(X, s)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _is_broken(loss: float, honest_loss: float, factor: float,
               margin: float) -> bool:
    return (not math.isfinite(loss)) or loss > honest_loss * factor + margin


def _honest_twin(sc: Scenario) -> Scenario:
    """The cell's zero-Byzantine baseline. The attack is canonicalized to
    ``sign_flip`` — with an empty Byzantine mask no buffer row is ever
    replaced and no init batch poisoned, so honest dynamics are attack-
    invariant and one baseline serves every attack of a configuration."""
    return sc._replace(byz_ids=(), attack="sign_flip", attack_params=(),
                       name="")


def breakdown_matrix(scenarios: List[Scenario], *, factor: float = 1.5,
                     margin: float = 0.25,
                     bisect_steps: Optional[int] = None,
                     time_aggs: bool = True,
                     cache: Optional[Dict[tuple, FleetGroup]] = None
                     ) -> List[dict]:
    """Evaluate every cell and bisect its breakdown point; returns one flat
    JSON-ready dict per input scenario (input order preserved).

    ``bisect_steps`` shortens the bisection probes' horizon (the honest
    envelope is re-measured at that horizon so the threshold stays
    comparable); by default probes run the cell's full ``steps``. Passing a
    ``cache`` shares compiled groups with the caller across matrix calls.
    """
    cache = {} if cache is None else cache
    n = len(scenarios)

    # main runs + deduped honest twins, one batched pass
    batch = list(scenarios)
    twin_ix: Dict[Scenario, int] = {}
    for sc in scenarios:
        twin = _honest_twin(sc)
        if twin not in twin_ix:
            twin_ix[twin] = len(batch)
            batch.append(twin)
    res = run_cached(batch, cache)
    main = res[:n]
    honest = {twin: res[j] for twin, j in twin_ix.items()}

    # honest envelope at the bisection horizon (reuse full-horizon runs when
    # the horizons coincide)
    def _short(sc: Scenario) -> Scenario:
        steps = sc.steps if bisect_steps is None else min(bisect_steps,
                                                          sc.steps)
        return sc._replace(steps=steps, name="")

    short_twins = {sc: _honest_twin(_short(sc)) for sc in scenarios}
    missing = [t for t in set(short_twins.values()) if t not in honest]
    for t, r in zip(missing, run_cached(missing, cache)):
        honest[t] = r

    # bisection over Byzantine count, batched across cells per iteration
    lo = [0] * n
    hi = [sc.m for sc in scenarios]
    for i, (sc, r) in enumerate(zip(scenarios, main)):
        # seed with the cell's own full run when horizons match
        if _short(sc).steps == sc.steps and 0 < len(
                resolved_byz_ids(sc)) < sc.m:
            h = honest[short_twins[sc]].eval["loss"]
            b = len(resolved_byz_ids(sc))
            if _is_broken(r.eval["loss"], h, factor, margin):
                hi[i] = b
            else:
                lo[i] = b
    while True:
        probe_ix = [i for i in range(n) if hi[i] - lo[i] > 1]
        if not probe_ix:
            break
        mids = {i: (lo[i] + hi[i]) // 2 for i in probe_ix}
        probes = [_short(scenarios[i])._replace(
            byz_ids=tuple(range(mids[i]))) for i in probe_ix]
        for i, r in zip(probe_ix, run_cached(probes, cache)):
            h = honest[short_twins[scenarios[i]]].eval["loss"]
            if _is_broken(r.eval["loss"], h, factor, margin):
                hi[i] = mids[i]
            else:
                lo[i] = mids[i]

    # standalone aggregator timings, one per distinct (agg, lam, backend, m, d)
    agg_us: Dict[tuple, float] = {}
    if time_aggs:
        for sc in scenarios:
            d = build_problem(sc).d
            key = (sc.agg, float(sc.lam), sc.agg_backend, sc.m, d)
            if key not in agg_us:
                agg_us[key] = time_agg_us(*key)

    rows = []
    for i, (sc, r) in enumerate(zip(scenarios, main)):
        h = honest[_honest_twin(sc)]
        d = build_problem(sc).d
        row = {
            "cell": sc.label,
            "problem": sc.problem, "attack": sc.attack, "agg": sc.agg,
            "arrival": sc.arrival,
            "alpha": "inf" if not math.isfinite(sc.alpha) else sc.alpha,
            "m": sc.m, "n_byz": len(resolved_byz_ids(sc)),
            "byz_frac": len(resolved_byz_ids(sc)) / sc.m,
            "seed": sc.seed, "steps": sc.steps, "weighted": sc.weighted,
            "final_loss": float(r.eval["loss"]),
            "honest_loss": float(h.eval["loss"]),
            "lambda_emp": r.lambda_emp,
            "engine_us_per_step": r.us_per_step,
            "breakdown_count": hi[i],
            "breakdown_frac": hi[i] / sc.m,
            "agg_us_per_call": agg_us.get(
                (sc.agg, float(sc.lam), sc.agg_backend, sc.m, d)),
        }
        if "acc" in r.eval:
            row["acc"] = float(r.eval["acc"])
            row["honest_acc"] = float(h.eval["acc"])
        rows.append(row)
    return rows


def matrix_scenarios(*, problem: str = "classifier",
                     attacks=("sign_flip", "little", "empire",
                              "adaptive_scale"),
                     aggs=("ctma:cwmed", "ctma:gm", "cwmed"),
                     arrivals=("proportional", "squared"),
                     alphas=(math.inf, 0.3),
                     m: int = 9, byz_frac: float = 2.0 / 9.0,
                     steps: int = 100, batch: int = 8, seeds=(0,),
                     lam: float = 0.38,
                     adaptive_params: tuple = ()) -> List[Scenario]:
    """The full cross-product grid — one Scenario per (attack × agg ×
    arrival × alpha × seed) cell. ``adaptive_params`` is attached to the
    adaptive attacks only (grid size / golden-section iterations tradeoff)."""
    from .adaptive import ADAPTIVE_ATTACKS
    return [
        Scenario(problem=problem, attack=at, agg=ag, lam=lam, m=m,
                 byz_frac=byz_frac, arrival=ar, alpha=al, seed=sd,
                 steps=steps, batch=batch,
                 attack_params=(tuple(adaptive_params)
                                if at in ADAPTIVE_ATTACKS else ()))
        for at in attacks for ag in aggs for ar in arrivals
        for al in alphas for sd in seeds
    ]


def matrix_rows(rows: List[dict]) -> List[str]:
    """Benchmark-orchestrator CSV lines (``name,value,unit,derived``) for a
    matrix — the value column carries the standalone aggregator µs/call
    (``unit=us``) and ``derived`` packs the robustness metrics, one
    ``robust_`` row per cell."""
    out = []
    for r in rows:
        derived = (f"loss={r['final_loss']:.4f}"
                   f";honest={r['honest_loss']:.4f}"
                   f";breakdown_frac={r['breakdown_frac']:.3f}"
                   f";lambda={r['lambda_emp']:.3f}"
                   f";step_us={r['engine_us_per_step']:.0f}")
        if "acc" in r:
            derived += f";acc={r['acc']:.4f}"
        us = r["agg_us_per_call"] or 0.0
        out.append(f"robust_{r['cell']},{us:.1f},us,{derived}")
    return out
