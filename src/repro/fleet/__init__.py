"""`repro.fleet` — vmapped adversarial scenario fleet for the training side.

The robustness claims of the paper are only as strong as the scenario
diversity they are checked against. This package evaluates the full matrix —
attack × arrival distribution × aggregator spec × worker count × Byzantine
fraction × data heterogeneity — cheaply, by vmapping ONE jitted Alg. 2 step
(`core.engine.engine_step`) over a leading scenario axis of stacked engine
states, so thousands of simulated workers advance per device step.

    Scenario        declarative spec; `compile_signature` groups scenarios
                    that can share one jit (scenario.py)
    FleetGroup /    the batched engine: stacked-state init, one vmapped step,
    run_scenarios   per-scenario snapshots + eval (batched.py)
    adaptive        attackers that tune their vector against the RESOLVED
                    aggregator inside jit (adaptive.py)
    matrix          breakdown-point bisection + the robustness-vs-cost
                    matrix persisted to BENCH_robust.json (matrix.py)

See `src/repro/fleet/README.md` for the scenario grammar and matrix schema.
"""
from .scenario import (  # noqa: F401
    PROBLEMS,
    Scenario,
    build_problem,
    compile_signature,
    engine_config,
    group_scenarios,
    resolved_byz_ids,
)
from .batched import FleetGroup, FleetResult, run_scenarios, run_sequential  # noqa: F401
from .adaptive import ADAPTIVE_ATTACKS, FLEET_ATTACKS, make_attack_fn  # noqa: F401
from .matrix import (  # noqa: F401
    breakdown_matrix,
    matrix_rows,
    matrix_scenarios,
    run_cached,
    time_agg_us,
)
