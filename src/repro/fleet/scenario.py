"""Declarative scenario specs + compile-signature grouping.

A :class:`Scenario` names ONE cell of the robustness matrix: attack ×
arrival/delay distribution × ``rule[:base][@backend]`` aggregator spec ×
worker count ``m`` × Byzantine fraction × data-heterogeneity ``alpha`` ×
seed, over a named problem family. Scenarios are grouped by
:func:`compile_signature` — everything that changes the TRACE of the jitted
Alg. 2 step (attack branch, aggregator, optimizer, arrival kind, shapes) is
in the signature; everything that is merely DATA (which workers are
Byzantine, arrival probabilities, heterogeneity level, seeds, weighted-rule
ablation) is traced, so one jit serves every scenario of a shape class and
the breakdown-point bisection sweeps Byzantine mass without a single
recompile.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import MLP_SMALL
from repro.core import AttackConfig, EngineConfig
from repro.data.synthetic import (heterogeneous_worker_batches,
                                  make_classification_data)
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier)
from repro.optim import OptConfig
from repro.utils import ravel_pytree_fn

INF = float("inf")

# Default μ²-SGD settings per problem family (the benches' historical values).
_OPT_CLS = OptConfig(name="mu2", lr=0.05, gamma=0.1, beta=0.25)
_OPT_QUAD = OptConfig(name="mu2", lr=0.02, gamma=0.1, beta=0.25)


class Scenario(NamedTuple):
    """One cell of the robustness matrix (all fields hashable).

    ``attack`` takes any static name from ``core.attacks.ATTACKS`` or an
    adaptive name from ``fleet.adaptive.ADAPTIVE_ATTACKS``;
    ``attack_params`` carries static attack knobs (epsilon, grid bounds, …)
    as a sorted kv-tuple. ``byz_frac`` resolves to the ``round(byz_frac·m)``
    LOWEST worker ids (the slowest arrivals under proportional/squared
    distributions — the paper's Fig. 2 regime) unless ``byz_ids`` pins them.
    ``alpha`` is the Dirichlet label-skew concentration (``inf`` = IID;
    quadratic scenarios read it as a per-worker mean-shift scale ``1/√alpha``).
    ``weighted=False`` feeds unit weights to the aggregator — the
    non-weighted-rule ablation — without leaving the compile group."""
    problem: str = "classifier"          # classifier | quadratic
    attack: str = "sign_flip"
    agg: str = "ctma:cwmed"
    lam: float = 0.38
    m: int = 9
    byz_frac: float = 2.0 / 9.0
    byz_ids: Optional[Tuple[int, ...]] = None
    arrival: str = "proportional"
    alpha: float = INF                   # data heterogeneity (inf = IID)
    seed: int = 0
    steps: int = 300
    batch: int = 8
    opt: Optional[OptConfig] = None      # None -> per-problem default
    weighted: bool = True
    byz_start_step: int = 0
    agg_backend: str = "jnp"
    attack_params: Tuple[Tuple[str, Any], ...] = ()
    name: str = ""

    @property
    def label(self) -> str:
        if self.name:
            return self.name
        agg = self.agg.replace(":", "-").replace("@", "_")
        alpha = "iid" if not math.isfinite(self.alpha) else f"a{self.alpha:g}"
        return (f"{self.problem}_{self.attack}_{agg}_{self.arrival}_{alpha}"
                f"_m{self.m}_b{len(resolved_byz_ids(self))}_s{self.seed}")

    @property
    def opt_resolved(self) -> OptConfig:
        if self.opt is not None:
            return self.opt
        return _OPT_CLS if self.problem == "classifier" else _OPT_QUAD


def resolved_byz_ids(sc: Scenario) -> Tuple[int, ...]:
    """The scenario's Byzantine worker ids (clipped so at least one honest
    worker always remains — the engine-level validation invariant)."""
    if sc.byz_ids is not None:
        return tuple(int(i) for i in sc.byz_ids)
    n = int(round(sc.byz_frac * sc.m))
    return tuple(range(min(max(n, 0), sc.m - 1)))


def engine_config(sc: Scenario) -> EngineConfig:
    """The :class:`EngineConfig` a scenario lowers to. Adaptive attacks keep
    ``attack='none'`` here — their vector comes from the ``attack_fn`` seam,
    not the static Appendix D branch."""
    from .adaptive import ADAPTIVE_ATTACKS
    static = sc.attack not in ADAPTIVE_ATTACKS
    akw = {k: v for k, v in sc.attack_params if k in AttackConfig._fields}
    attack = AttackConfig(sc.attack, **akw) if static else AttackConfig("none")
    return EngineConfig(
        m=sc.m, byz=resolved_byz_ids(sc), attack=attack, agg=sc.agg,
        lam=sc.lam, opt=sc.opt_resolved, arrival=sc.arrival,
        byz_start_step=sc.byz_start_step, seed=sc.seed,
        agg_backend=sc.agg_backend).validate()


def compile_signature(sc: Scenario) -> tuple:
    """Hashable key of everything that changes the jitted step's trace.

    Scenarios with equal signatures share ONE compiled vmapped step; their
    per-scenario knobs (byz ids, arrival probabilities, alpha, seed,
    weighted flag) ride in as traced arguments. Note ``arrival`` collapses to
    sampled-vs-round-robin: the three sampled distributions differ only in
    the traced probability vector."""
    arrival_kind = "rr" if sc.arrival == "round_robin" else "sampled"
    return (sc.problem, sc.m, sc.batch, sc.attack, sc.attack_params, sc.agg,
            float(sc.lam), sc.agg_backend, arrival_kind, sc.opt_resolved,
            int(sc.byz_start_step))


def group_scenarios(scenarios: List[Scenario]) -> Dict[tuple, List[int]]:
    """Indices of ``scenarios`` grouped by :func:`compile_signature`
    (insertion-ordered, so results can be re-scattered to input order)."""
    groups: Dict[tuple, List[int]] = {}
    for i, sc in enumerate(scenarios):
        groups.setdefault(compile_signature(sc), []).append(i)
    return groups


# ---------------------------------------------------------------------------
# Problem families
# ---------------------------------------------------------------------------

class Problem(NamedTuple):
    """Everything the batched engine needs from a problem family: the flat
    loss, parameter init, the per-worker batch stream (data heterogeneity
    lives here), and the held-out evaluation."""
    d: int
    loss_fn: Callable                    # loss(flat_params, batch) -> scalar
    init_params: Callable                # (sc) -> (d,) float32
    stream: Callable                     # (sc) -> iterator of per-worker stacks
    evaluate: Callable                   # (flat_params, sc) -> dict


_QUAD_D = 30
_QUAD_WSTAR = np.full((_QUAD_D,), 2.0, np.float32)


def _quad_problem() -> Problem:
    wstar = jnp.asarray(_QUAD_WSTAR)

    def loss_fn(w, batch):
        return 0.5 * jnp.mean(jnp.sum((w - wstar - batch["x"]) ** 2, -1)) \
            + 0.0 * jnp.sum(batch["y"])

    def init_params(sc: Scenario):
        return jnp.zeros((_QUAD_D,), jnp.float32)

    def stream(sc: Scenario):
        rng = np.random.default_rng([sc.seed, 0x0_AD])
        het = 0.0 if not math.isfinite(sc.alpha) else 1.0 / np.sqrt(sc.alpha)
        shift = (het * np.random.default_rng([sc.seed, 0x5F7])
                 .normal(size=(sc.m, 1, _QUAD_D))).astype(np.float32)
        while True:
            x = rng.normal(size=(sc.m, sc.batch, _QUAD_D)).astype(np.float32)
            yield {"x": x + shift, "y": np.zeros((sc.m, sc.batch), np.int32)}

    def evaluate(flat, sc: Scenario) -> dict:
        # excess loss f(x_T) - f(x*) = 0.5·||x_T - w*||² (+ const noise var)
        excess = 0.5 * float(jnp.sum((flat - wstar) ** 2))
        return {"loss": excess, "excess": excess}

    return Problem(_QUAD_D, loss_fn, init_params, stream, evaluate)


_CLS_KW = dict(image_hw=MLP_SMALL.image_hw, channels=MLP_SMALL.channels,
               n_classes=MLP_SMALL.n_classes, seed=0, sigma=1.6)


def _cls_problem() -> Problem:
    flat0, unravel = ravel_pytree_fn(
        init_classifier(jax.random.PRNGKey(0), MLP_SMALL))

    def loss_fn(w, batch):
        return classifier_loss(unravel(w), MLP_SMALL, batch)

    def init_params(sc: Scenario):
        flat, _ = ravel_pytree_fn(
            init_classifier(jax.random.PRNGKey(sc.seed), MLP_SMALL))
        return flat

    def stream(sc: Scenario):
        it = heterogeneous_worker_batches(
            sc.m, sc.batch, alpha=sc.alpha, sample_seed=sc.seed + 1,
            shard_seed=sc.seed, **_CLS_KW)
        for b in it:
            yield {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    def evaluate(flat, sc: Scenario) -> dict:
        test = make_classification_data(1024, sample_seed=10_000 + sc.seed,
                                        **_CLS_KW)
        batch = {"x": jnp.asarray(test["x"]), "y": jnp.asarray(test["y"])}
        params = unravel(flat)
        return {"loss": float(classifier_loss(params, MLP_SMALL, batch)),
                "acc": float(classifier_accuracy(params, MLP_SMALL, batch))}

    return Problem(int(flat0.shape[0]), loss_fn, init_params, stream, evaluate)


PROBLEMS: Dict[str, Callable[[], Problem]] = {
    "quadratic": _quad_problem,
    "classifier": _cls_problem,
}


def build_problem(sc: Scenario) -> Problem:
    """Instantiate the scenario's problem family (one per group — every
    scenario in a compile group shares the problem by construction)."""
    if sc.problem not in PROBLEMS:
        raise KeyError(f"unknown problem {sc.problem!r}; "
                       f"choose from {sorted(PROBLEMS)}")
    return PROBLEMS[sc.problem]()
