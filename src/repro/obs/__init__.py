"""repro.obs — jit-safe telemetry, structured tracing, run reports.

Three pieces (see obs/README.md for the metric catalog + trace schema):

- :mod:`metrics` — a typed metric registry (counter / gauge / histogram
  with static bucket edges), jit-safe collection helpers (instrumented
  steps return a shape-static metrics pytree next to their outputs), and a
  host-side :class:`~repro.obs.metrics.MetricSink` streaming validated rows
  to JSONL.
- :mod:`trace` — a host-side span :class:`~repro.obs.trace.Tracer`
  (admission → prefill → insert → decode per request, step / warmup /
  eviction events, XLA compiles folded in via the ``lint_runtime`` event
  names) with Chrome-trace / Perfetto JSON export.
- :mod:`report` — render a run's JSONL (+ optional trace) into a
  text/markdown summary: histograms, quarantine timeline, per-replica
  health. CLI: ``python -m repro.launch.obs``.

:class:`RunObs` bundles one sink + one tracer behind the single optional
``obs=`` handle every instrumented layer takes (``core.engine`` run loops,
``serve.engine`` / ``serve.replicated``, ``fleet.batched``, the serve
benchmarks). ``obs=None`` — the default everywhere — is the zero-cost-off
path: no sink, no tracer, and the jitted steps lower to the uninstrumented
HLO because the ``collect_metrics`` flags they key on stay statically
False.
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext
from pathlib import Path
from typing import Any, Dict, Iterator, Optional, Union

from repro.obs.metrics import (EVENTS, MASS_EDGES, REGISTRY, EventSpec,
                               MetricSink, MetricSpec, histogram, load_jsonl,
                               register, register_event, validate_jsonl,
                               validate_rows)
from repro.obs.report import render_summary, summarize_files
from repro.obs.trace import Tracer, validate_trace

__all__ = [
    "EVENTS", "EventSpec", "MASS_EDGES", "MetricSink", "MetricSpec",
    "REGISTRY", "RunObs", "Tracer", "histogram", "load_jsonl", "register",
    "register_event", "render_summary", "summarize_files", "validate_jsonl",
    "validate_rows", "validate_trace",
]


class RunObs:
    """One observed run: a :class:`MetricSink` and/or a :class:`Tracer`.

    Either half may be absent — every method no-ops against a missing half,
    so instrumentation sites stay a single unconditional call once they have
    a non-None handle. ``device_metrics`` is the STATIC enabled flag the
    engines consult when building their jitted steps: True compiles the
    metric-collecting step variants (same compile count, extra shape-static
    outputs), False keeps the uninstrumented HLO even while host-side
    rows/spans are still recorded."""

    def __init__(self, sink: Optional[MetricSink] = None,
                 tracer: Optional[Tracer] = None,
                 device_metrics: bool = True):
        self.sink = sink
        self.tracer = tracer
        self.device_metrics = device_metrics

    @classmethod
    def open(cls, directory: Union[str, Path], prefix: str,
             device_metrics: bool = True,
             compile_events: bool = True) -> "RunObs":
        """Sink + tracer writing ``<dir>/<prefix>.metrics.jsonl`` and
        ``<dir>/<prefix>.trace.json``; XLA compile events attached."""
        d = Path(directory)
        obs = cls(sink=MetricSink(d / f"{prefix}.metrics.jsonl"),
                  tracer=Tracer(d / f"{prefix}.trace.json"),
                  device_metrics=device_metrics)
        if compile_events:
            obs.tracer.attach_compile_events()
        return obs

    # -- metrics -----------------------------------------------------------

    def metric(self, name: str, value: Any, step: Optional[int] = None,
               **labels: Any) -> None:
        if self.sink is not None:
            self.sink.log(name, value, step=step, **labels)

    def metric_tree(self, tree: Dict[str, Any], step: Optional[int] = None,
                    **labels: Any) -> None:
        if self.sink is not None:
            self.sink.log_tree(tree, step=step, **labels)

    def event(self, name: str, step: Optional[int] = None,
              **fields: Any) -> None:
        """Structured event: a JSONL row AND an instant on the timeline."""
        if self.sink is not None:
            self.sink.event(name, step=step, **fields)
        if self.tracer is not None:
            self.tracer.instant(name, cat="event", step=step, **fields)

    # -- timeline ----------------------------------------------------------

    def span(self, name: str, **args: Any):
        if self.tracer is None:
            return nullcontext()
        return self.tracer.span(name, **args)

    def counter(self, name: str, **values: float) -> None:
        if self.tracer is not None:
            self.tracer.counter(name, **values)

    def request_begin(self, uid: int, **args: Any) -> None:
        if self.tracer is not None:
            self.tracer.begin_async("request", uid, **args)

    def request_end(self, uid: int, **args: Any) -> None:
        if self.tracer is not None:
            self.tracer.end_async("request", uid, **args)

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()
        if self.tracer is not None:
            self.tracer.close()


@contextmanager
def observed_run(directory: Union[str, Path], prefix: str,
                 device_metrics: bool = True) -> Iterator[RunObs]:
    """``with observed_run("obs_out", "serve") as obs: ...`` — opens sink +
    tracer, guarantees flush/export on exit."""
    obs = RunObs.open(directory, prefix, device_metrics=device_metrics)
    try:
        yield obs
    finally:
        obs.close()
