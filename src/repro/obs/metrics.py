"""Typed metric registry + jit-safe collection + host-side JSONL sink.

The registry is the single source of truth for every telemetry name the
repo emits: a metric is declared ONCE (``register``) with a kind
(``counter`` / ``gauge`` / ``histogram``), a unit and a description, and the
obs README's catalog is lint-gated against it (RD203 in
``tools/lint/docs_rules.py``) so the docs can never silently drift from the
code. Structured events (quarantine transitions, request lifecycle) live in
a parallel ``register_event`` catalog.

Jit-side collection is SHAPE-STATIC by construction: instrumented steps
return a metrics pytree (scalars / fixed-size vectors / fixed-bucket
histogram counts) alongside their existing outputs — no ``io_callback``, no
host round-trips inside jit. :func:`histogram` bucketizes against STATIC
edges (a Python tuple baked into the trace), so an enabled run compiles
exactly once per step like a disabled one; a disabled run (the default)
omits the extra outputs entirely and lowers to the uninstrumented HLO.

Host-side, a :class:`MetricSink` validates each row against the registry
and appends it to JSONL — one JSON object per line, ``{"metric": name,
"kind": ..., "unit": ..., "step": ..., "value": ...}`` for samples and
``{"event": name, "step": ..., **fields}`` for events.
:func:`validate_jsonl` re-checks a file against the same schema (the CI obs
smoke gate).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

KINDS = ("counter", "gauge", "histogram")

# static bucket edges for weight-mass histograms: masses are fractions in
# [0, 1]; the log-ish spacing resolves both the starved tail and the
# dominant-worker head of a skewed arrival distribution
MASS_EDGES: Tuple[float, ...] = (0.01, 0.02, 0.05, 0.1, 0.2, 0.4, 0.8)

# decode-step / prefill-call wall-time edges (seconds)
TIME_EDGES: Tuple[float, ...] = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1)


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One registered metric: its kind decides how rows are validated and
    rendered (counters accumulate, gauges sample, histograms carry
    per-bucket counts against ``bucket_edges``)."""
    name: str
    kind: str                       # counter | gauge | histogram
    unit: str = ""
    desc: str = ""
    bucket_edges: Tuple[float, ...] = ()


@dataclasses.dataclass(frozen=True)
class EventSpec:
    """One registered structured-event name."""
    name: str
    desc: str = ""


REGISTRY: Dict[str, MetricSpec] = {}
EVENTS: Dict[str, EventSpec] = {}


def register(name: str, kind: str, unit: str = "", desc: str = "",
             bucket_edges: Sequence[float] = ()) -> MetricSpec:
    """Declare a metric. Re-registration must be identical (idempotent
    imports); a conflicting redeclaration is a programming error."""
    if kind not in KINDS:
        raise ValueError(f"metric {name!r}: unknown kind {kind!r} "
                         f"(choose from {KINDS})")
    if kind == "histogram" and not bucket_edges:
        raise ValueError(f"histogram metric {name!r} needs static "
                         f"bucket_edges")
    spec = MetricSpec(name, kind, unit, desc, tuple(bucket_edges))
    prev = REGISTRY.get(name)
    if prev is not None and prev != spec:
        raise ValueError(f"metric {name!r} re-registered with a different "
                         f"spec: {prev} vs {spec}")
    REGISTRY[name] = spec
    return spec


def register_event(name: str, desc: str = "") -> EventSpec:
    spec = EventSpec(name, desc)
    prev = EVENTS.get(name)
    if prev is not None and prev != spec:
        raise ValueError(f"event {name!r} re-registered with a different "
                         f"description")
    EVENTS[name] = spec
    return spec


# ---------------------------------------------------------------------------
# jit-safe collection
# ---------------------------------------------------------------------------

def histogram(values: Array, edges: Sequence[float],
              weights: Optional[Array] = None) -> Array:
    """Shape-static bucket counts of ``values`` against STATIC ``edges``.

    Returns ``(len(edges) + 1,)`` counts — bucket ``i`` holds values in
    ``[edges[i-1], edges[i])`` with the open tails at both ends. ``edges``
    must be a Python sequence (baked into the trace); ``weights`` optionally
    accumulates per-value mass instead of counts. Safe to call inside jit:
    no data-dependent shapes, no host sync."""
    e = jnp.asarray(tuple(edges), jnp.float32)
    v = jnp.ravel(values).astype(jnp.float32)
    idx = jnp.searchsorted(e, v, side="right")
    w = (jnp.ones_like(v) if weights is None
         else jnp.ravel(weights).astype(jnp.float32))
    return jnp.zeros((len(tuple(edges)) + 1,), jnp.float32).at[idx].add(w)


def bucketize(values: Sequence[float],
              edges: Sequence[float]) -> List[float]:
    """HOST-side counterpart of :func:`histogram` (same bucket semantics:
    ``len(edges) + 1`` counts, half-open ``[lo, hi)`` buckets with open
    tails) for wall-clock samples collected outside jit."""
    edges = list(edges)
    counts = np.histogram(np.asarray(list(values), np.float64),
                          bins=[-np.inf] + edges + [np.inf])[0]
    return [float(c) for c in counts]


# ---------------------------------------------------------------------------
# host-side sink
# ---------------------------------------------------------------------------

def _to_py(value: Any):
    """Device/NumPy values -> JSON-serializable Python (scalars or nested
    lists). The single host sync point of the metrics path."""
    if isinstance(value, (int, float, str, bool)) or value is None:
        return value
    arr = np.asarray(value)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


class MetricSink:
    """Accumulates metric rows / events and streams them to JSONL.

    ``path=None`` keeps rows in memory only (tests). Every ``log`` is
    validated against the registry — an unregistered name raises, which is
    what keeps the README catalog (lint-gated against the registry)
    equivalent to the data actually on disk."""

    def __init__(self, path: Optional[Union[str, Path]] = None):
        self.path = Path(path) if path is not None else None
        self.rows: List[dict] = []
        self._fh = None
        if self.path is not None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open("w")

    def _write(self, row: dict) -> None:
        self.rows.append(row)
        if self._fh is not None:
            self._fh.write(json.dumps(row) + "\n")

    def log(self, name: str, value: Any, step: Optional[int] = None,
            **labels: Any) -> None:
        spec = REGISTRY.get(name)
        if spec is None:
            raise KeyError(f"metric {name!r} is not registered — declare it "
                           f"in repro.obs.metrics (and the obs README "
                           f"catalog)")
        row = {"metric": name, "kind": spec.kind, "unit": spec.unit,
               "step": int(step) if step is not None else None,
               "value": _to_py(value)}
        for k, v in labels.items():
            row[k] = _to_py(v)
        self._write(row)

    def log_tree(self, tree: Dict[str, Any], step: Optional[int] = None,
                 **labels: Any) -> None:
        """Log every ``{registered-name: value}`` entry of a metrics pytree
        returned by an instrumented jitted step."""
        for name, value in tree.items():
            self.log(name, value, step=step, **labels)

    def event(self, name: str, step: Optional[int] = None,
              **fields: Any) -> None:
        if name not in EVENTS:
            raise KeyError(f"event {name!r} is not registered — declare it "
                           f"in repro.obs.metrics (and the obs README "
                           f"catalog)")
        row = {"event": name,
               "step": int(step) if step is not None else None}
        for k, v in fields.items():
            row[k] = _to_py(v)
        self._write(row)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


# ---------------------------------------------------------------------------
# schema validation (the CI obs smoke gate)
# ---------------------------------------------------------------------------

def _is_number(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _numeric(value) -> bool:
    if _is_number(value):
        return True
    if isinstance(value, list):
        return all(_numeric(v) for v in value)
    return False


def validate_rows(rows: List[dict]) -> List[str]:
    """Schema-check parsed JSONL rows; returns human-readable errors
    (empty = valid). Every row must be a metric sample of a registered
    metric with a numeric value (histograms: a count vector whose trailing
    dim is ``len(edges) + 1``) or a registered event."""
    errors = []
    for i, row in enumerate(rows):
        where = f"row {i + 1}"
        if not isinstance(row, dict):
            errors.append(f"{where}: not a JSON object")
            continue
        is_metric, is_event = "metric" in row, "event" in row
        if is_metric == is_event:
            errors.append(f"{where}: needs exactly one of 'metric'/'event'")
            continue
        step = row.get("step")
        if step is not None and not isinstance(step, int):
            errors.append(f"{where}: non-integer step {step!r}")
        if is_event:
            if row["event"] not in EVENTS:
                errors.append(f"{where}: unregistered event {row['event']!r}")
            continue
        spec = REGISTRY.get(row["metric"])
        if spec is None:
            errors.append(f"{where}: unregistered metric {row['metric']!r}")
            continue
        if row.get("kind") != spec.kind or row.get("unit") != spec.unit:
            errors.append(f"{where}: {row['metric']}: kind/unit mismatch vs "
                          f"registry ({row.get('kind')!r}/{row.get('unit')!r}"
                          f" != {spec.kind!r}/{spec.unit!r})")
        value = row.get("value")
        if not _numeric(value):
            errors.append(f"{where}: {row['metric']}: non-numeric value")
        elif spec.kind == "histogram":
            v = value if isinstance(value, list) else [value]
            inner = v
            while inner and isinstance(inner[0], list):
                inner = inner[0]
            if len(inner) != len(spec.bucket_edges) + 1:
                errors.append(
                    f"{where}: {row['metric']}: histogram has {len(inner)} "
                    f"buckets, registry edges imply "
                    f"{len(spec.bucket_edges) + 1}")
    return errors


def load_jsonl(path: Union[str, Path]) -> List[dict]:
    rows = []
    for ln, line in enumerate(Path(path).read_text().splitlines(), start=1):
        if not line.strip():
            continue
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}:{ln}: invalid JSON ({e.msg})") from e
    return rows


def validate_jsonl(path: Union[str, Path]) -> List[str]:
    """Parse + schema-check a metrics JSONL file; returns errors."""
    try:
        rows = load_jsonl(path)
    except ValueError as e:
        return [str(e)]
    return validate_rows(rows)


# ---------------------------------------------------------------------------
# THE metric catalog — every name the instrumented layers emit. The obs
# README's tables are lint-gated against these declarations (RD203).
# ---------------------------------------------------------------------------

# core async engine (Alg. 2) — repro.core.engine with collect_metrics=True
register("engine.loss", "gauge", unit="nats",
         desc="arriving worker's minibatch loss at its query point")
register("engine.lambda_emp", "gauge", unit="frac",
         desc="empirical Byzantine-update fraction so far (Eq. 6 lambda)")
register("engine.staleness", "gauge", unit="steps",
         desc="server iterations since the arriving worker's previous "
              "arrival (host-derived from the worker stream)")
register("engine.weight_mass", "gauge", unit="frac",
         desc="(m,) normalized aggregation-weight mass per worker")
register("engine.weight_mass_hist", "histogram", unit="workers",
         desc="per-worker weight-mass distribution", bucket_edges=MASS_EDGES)
register("engine.byz_mass", "gauge", unit="frac",
         desc="weight mass the robust rule sees on Byzantine rows")
register("engine.anchor_dist", "gauge", unit="l2",
         desc="global L2 distance between the robust aggregate and the "
              "weighted mean of the momentum buffer")

# fleet runner — repro.fleet.batched
register("fleet.loss", "gauge", unit="nats",
         desc="per-scenario step loss (one vector row per fleet step)")

# serve engines — repro.serve.engine / repro.serve.replicated
register("serve.queue_depth", "gauge", unit="requests",
         desc="requests waiting in the admission scheduler")
register("serve.slot_occupancy", "gauge", unit="frac",
         desc="useful (non-retired) slot rows this decode step / n_slots")
register("serve.page_occupancy", "gauge", unit="frac",
         desc="physical KV pages in use / pool size (paged cache only)")
register("serve.prefill_s", "gauge", unit="s",
         desc="wall seconds of one prefill+insert+first-token call",
         )
register("serve.prefill_s_hist", "histogram", unit="calls",
         desc="prefill call wall-time distribution", bucket_edges=TIME_EDGES)
register("serve.decode_s", "gauge", unit="s",
         desc="wall seconds of one decode step over all slots")
register("serve.decode_s_hist", "histogram", unit="steps",
         desc="decode step wall-time distribution", bucket_edges=TIME_EDGES)
register("serve.prefill_tokens", "counter", unit="tokens",
         desc="prompt tokens prefilled (cumulative)")
register("serve.gen_tokens", "counter", unit="tokens",
         desc="tokens generated (cumulative)")

# replicated voting — repro.serve.replicated / dist.steps replicated decode
register("serve.replica.vote_mass", "gauge", unit="mass",
         desc="(R,) per-replica vote mass entering this step's vote "
              "(staleness x availability x quarantine)")
register("serve.replica.score", "gauge", unit="score",
         desc="(R,) per-replica Zeno++-style pre-vote score, median over "
              "active slots")
register("serve.vote.disagree_mass", "gauge", unit="frac",
         desc="(S,) fraction of vote mass whose replica argmax disagrees "
              "with the voted token (device-collected)")
register("serve.vote.margin", "gauge", unit="logit",
         desc="(S,) top1-top2 margin of the voted logits "
              "(device-collected)")

# structured events
register_event("serve.request.admit",
               desc="request admitted to a slot (uid, slot, prompt_len)")
register_event("serve.request.finish",
               desc="request finished (uid, slot, gen_tokens, eos)")
register_event("serve.quarantine.evict",
               desc="replica evicted from the vote: step, replica, score at "
                    "eviction, backoff, active request uids")
register_event("serve.quarantine.readmit",
               desc="replica re-admitted after backoff: step, replica, "
                    "evictions so far")
register_event("fleet.group",
               desc="one fleet compile group: group id, scenario labels")
