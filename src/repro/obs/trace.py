"""Host-side span tracer with Chrome-trace / Perfetto JSON export.

Collects timeline events while an engine runs — complete spans
(``ph="X"``: prefill calls, decode steps, warmup), instant events
(``ph="i"``: quarantine transitions, request retirement), counter tracks
(``ph="C"``: queue depth, slot occupancy) and async request lifetimes
(``ph="b"``/``"e"`` keyed by request uid) — and exports them as the Chrome
trace-event JSON Perfetto loads directly (``ui.perfetto.dev`` → open file).
Timestamps are microseconds from tracer construction on
``time.perf_counter``.

XLA compiles are folded in as first-class trace events:
:meth:`Tracer.attach_compile_events` registers a ``jax.monitoring``
duration listener on the same events as
:mod:`repro.lint_runtime.compile_count` (backend compiles + jaxpr traces),
so every compile shows up as a span on its own track — warmup cost and any
mid-run recompile are visible on the exact timeline the serving spans live
on, instead of being a bare counter in a test.

The tracer is append-only and lock-guarded (the monitoring listener fires
from whatever thread compiled), and export is a plain ``json.dump`` — no
engine ever blocks on tracing beyond the list append.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Union

from repro.lint_runtime import (BACKEND_COMPILE_EVENT, TRACE_EVENT,
                                _unregister)

# track (tid) layout of the exported timeline
TID_ENGINE = 1          # prefill / decode / warmup spans + counters
TID_COMPILE = 2         # XLA backend compiles + jaxpr traces
TID_REQUESTS = 3        # async request lifetimes
_TID_NAMES = {TID_ENGINE: "engine", TID_COMPILE: "xla_compile",
              TID_REQUESTS: "requests"}


class Tracer:
    """Chrome-trace event collector; one instance per observed run."""

    def __init__(self, path: Optional[Union[str, Path]] = None,
                 pid: int = 1):
        self.path = Path(path) if path is not None else None
        self.pid = pid
        self.events: List[dict] = []
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._compile_listener = None
        for tid, name in _TID_NAMES.items():
            self._push({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": name}})

    # -- low-level ---------------------------------------------------------

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _push(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    # -- event kinds -------------------------------------------------------

    @contextmanager
    def span(self, name: str, cat: str = "engine", tid: int = TID_ENGINE,
             **args: Any) -> Iterator[None]:
        """Complete event around a block of work."""
        ts = self.now_us()
        try:
            yield
        finally:
            self._push({"name": name, "cat": cat, "ph": "X", "ts": ts,
                        "dur": self.now_us() - ts, "pid": self.pid,
                        "tid": tid, "args": args})

    def complete(self, name: str, ts_us: float, dur_us: float,
                 cat: str = "engine", tid: int = TID_ENGINE,
                 **args: Any) -> None:
        """Record an already-timed span (e.g. a compile whose duration the
        listener reports after the fact)."""
        self._push({"name": name, "cat": cat, "ph": "X", "ts": ts_us,
                    "dur": dur_us, "pid": self.pid, "tid": tid,
                    "args": args})

    def instant(self, name: str, cat: str = "engine",
                tid: int = TID_ENGINE, **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self.now_us(), "pid": self.pid, "tid": tid,
                    "args": args})

    def counter(self, name: str, **values: float) -> None:
        """Counter track sample, e.g. ``counter("queue", depth=3)``."""
        self._push({"name": name, "cat": "engine", "ph": "C",
                    "ts": self.now_us(), "pid": self.pid, "tid": TID_ENGINE,
                    "args": {k: float(v) for k, v in values.items()}})

    def begin_async(self, name: str, aid: int, cat: str = "request",
                    **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "b", "id": int(aid),
                    "ts": self.now_us(), "pid": self.pid,
                    "tid": TID_REQUESTS, "args": args})

    def end_async(self, name: str, aid: int, cat: str = "request",
                  **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "e", "id": int(aid),
                    "ts": self.now_us(), "pid": self.pid,
                    "tid": TID_REQUESTS, "args": args})

    # -- compile events (lint_runtime fold-in) -----------------------------

    def attach_compile_events(self) -> None:
        """Record every XLA backend compile / jaxpr trace as a span on the
        compile track until :meth:`detach_compile_events` (or close)."""
        if self._compile_listener is not None:
            return
        from jax import monitoring

        names = {BACKEND_COMPILE_EVENT: "xla_backend_compile",
                 TRACE_EVENT: "jaxpr_trace"}

        def listener(event: str, duration: float, **_kw: Any) -> None:
            label = names.get(event)
            if label is None:
                return
            dur_us = duration * 1e6
            # the listener fires at completion: backdate the span start
            self.complete(label, ts_us=max(self.now_us() - dur_us, 0.0),
                          dur_us=dur_us, cat="compile", tid=TID_COMPILE)

        monitoring.register_event_duration_secs_listener(listener)
        self._compile_listener = listener

    def detach_compile_events(self) -> None:
        if self._compile_listener is not None:
            _unregister(self._compile_listener)
            self._compile_listener = None

    # -- export ------------------------------------------------------------

    def export(self, path: Optional[Union[str, Path]] = None) -> dict:
        """Write (and return) the Chrome-trace JSON document."""
        with self._lock:
            doc: Dict[str, Any] = {"traceEvents": list(self.events),
                                   "displayTimeUnit": "ms"}
        out = Path(path) if path is not None else self.path
        if out is not None:
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(doc))
        return doc

    def close(self) -> None:
        self.detach_compile_events()
        self.export()


def validate_trace(path: Union[str, Path]) -> List[str]:
    """Cheap Perfetto-loadability check of an exported trace file: valid
    JSON, a ``traceEvents`` list, and every event carrying the required
    ``ph``/``name``/``ts`` (metadata events excepted for ``ts``)."""
    try:
        doc = json.loads(Path(path).read_text())
    except json.JSONDecodeError as e:
        return [f"{path}: invalid JSON ({e.msg})"]
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return [f"{path}: missing traceEvents list"]
    errors = []
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            errors.append(f"{path}: event {i} missing ph/name")
            continue
        if ev["ph"] != "M" and not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"{path}: event {i} ({ev['name']}) missing ts")
        if ev["ph"] == "X" and not isinstance(ev.get("dur"), (int, float)):
            errors.append(f"{path}: event {i} ({ev['name']}) missing dur")
    return errors
