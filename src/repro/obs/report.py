"""Render an observed run's metrics JSONL (+ optional trace) as text/markdown.

This is the "what happened" half of repro.obs: ``render_summary`` takes the
parsed rows a :class:`~repro.obs.metrics.MetricSink` wrote and produces the
summary a human reads after a run — per-metric stats, ASCII histograms
against the registry's static bucket edges, the quarantine timeline
(evict → backoff → readmit with scores and displaced request uids),
per-replica health (vote mass + score trajectories), and per-scenario fleet
loss first→last. ``python -m repro.launch.obs`` is the CLI wrapper.

Scalars and vectors share one path: a vector-valued gauge row (e.g. the
``(R,)`` per-replica vote mass) contributes each of its components, keyed by
index, so "per-replica health" is just a pivot of the same rows.
"""
from __future__ import annotations

import json
from collections import defaultdict
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

from repro.obs.metrics import REGISTRY, load_jsonl

_BAR = "#"
_BAR_WIDTH = 30


def _flatten(value) -> List[float]:
    if isinstance(value, list):
        out: List[float] = []
        for v in value:
            out.extend(_flatten(v))
        return out
    return [float(value)]


def _fmt(x: float) -> str:
    if x != x:  # NaN
        return "nan"
    if x == int(x) and abs(x) < 1e6:
        return str(int(x))
    return f"{x:.4g}"


def _bucket_labels(edges: Sequence[float]) -> List[str]:
    labels = [f"< {_fmt(edges[0])}"]
    labels += [f"[{_fmt(lo)}, {_fmt(hi)})"
               for lo, hi in zip(edges[:-1], edges[1:])]
    labels.append(f">= {_fmt(edges[-1])}")
    return labels


def _ascii_hist(counts: Sequence[float], edges: Sequence[float],
                indent: str = "    ") -> List[str]:
    peak = max(counts) if counts and max(counts) > 0 else 1.0
    labels = _bucket_labels(edges)
    width = max(len(l) for l in labels)
    lines = []
    for label, c in zip(labels, counts):
        bar = _BAR * int(round(_BAR_WIDTH * c / peak))
        lines.append(f"{indent}{label:>{width}} | {bar} {_fmt(c)}")
    return lines


def _stats(values: List[float]) -> Dict[str, float]:
    n = len(values)
    mean = sum(values) / n
    return {"n": n, "min": min(values), "max": max(values), "mean": mean,
            "last": values[-1]}


# ---------------------------------------------------------------------------
# section renderers — each returns a list of lines (possibly empty)
# ---------------------------------------------------------------------------

def _metric_table(rows: List[dict], md: bool) -> List[str]:
    by_name: Dict[str, List[dict]] = defaultdict(list)
    for row in rows:
        if "metric" in row:
            by_name[row["metric"]].append(row)
    if not by_name:
        return []
    lines = ["## Metrics" if md else "Metrics", ""]
    if md:
        lines += ["| metric | kind | unit | rows | min | mean | max | last |",
                  "|---|---|---|---:|---:|---:|---:|---:|"]
    hist_sections: List[str] = []
    for name in sorted(by_name):
        mrows = by_name[name]
        spec = REGISTRY.get(name)
        kind = spec.kind if spec else mrows[-1].get("kind", "?")
        unit = spec.unit if spec else mrows[-1].get("unit", "")
        if kind == "histogram" and spec is not None:
            total = [0.0] * (len(spec.bucket_edges) + 1)
            for row in mrows:
                flat = _flatten(row["value"])
                # vector of histograms (e.g. vmapped fleet): fold buckets
                for i, v in enumerate(flat):
                    total[i % len(total)] += v
            hist_sections.append("")
            hist_sections.append(f"{'**' if md else ''}{name}{'**' if md else ''}"
                                 f" ({unit}, {len(mrows)} rows)")
            if md:
                hist_sections.append("```")
            hist_sections.extend(_ascii_hist(total, spec.bucket_edges))
            if md:
                hist_sections.append("```")
            continue
        values = [v for row in mrows for v in _flatten(row["value"])]
        s = _stats(values)
        if md:
            lines.append(f"| `{name}` | {kind} | {unit} | {s['n']} | "
                         f"{_fmt(s['min'])} | {_fmt(s['mean'])} | "
                         f"{_fmt(s['max'])} | {_fmt(s['last'])} |")
        else:
            lines.append(f"  {name:<28} {kind:<9} {unit:<8} n={s['n']:<6} "
                         f"min={_fmt(s['min'])} mean={_fmt(s['mean'])} "
                         f"max={_fmt(s['max'])} last={_fmt(s['last'])}")
    lines.extend(hist_sections)
    return lines


def _quarantine_timeline(rows: List[dict], md: bool) -> List[str]:
    events = [r for r in rows
              if r.get("event", "").startswith("serve.quarantine.")]
    if not events:
        return []
    lines = ["## Quarantine timeline" if md else "Quarantine timeline", ""]
    for e in events:
        kind = e["event"].rsplit(".", 1)[-1]
        step = e.get("step")
        parts = [f"step {step}" if step is not None else "step ?",
                 f"replica {e.get('replica', '?')}", kind]
        if "score" in e and e["score"] is not None:
            parts.append(f"score={_fmt(float(e['score']))}")
        if "backoff" in e:
            parts.append(f"backoff={e['backoff']}")
        if e.get("requests"):
            parts.append(f"requests={e['requests']}")
        if "evictions" in e:
            parts.append(f"evictions={e['evictions']}")
        prefix = "- " if md else "  "
        lines.append(prefix + "  ".join(str(p) for p in parts))
    return lines


def _replica_health(rows: List[dict], md: bool) -> List[str]:
    """Pivot the (R,)-vector serve.replica.* gauges into one line per
    replica: first/last vote mass, last score, eviction count."""
    mass: Dict[int, List[float]] = defaultdict(list)
    score: Dict[int, List[float]] = defaultdict(list)
    evictions: Dict[int, int] = defaultdict(int)
    for row in rows:
        name = row.get("metric")
        if name in ("serve.replica.vote_mass", "serve.replica.score"):
            dest = mass if name.endswith("vote_mass") else score
            for r, v in enumerate(_flatten(row["value"])):
                dest[r].append(v)
        elif row.get("event") == "serve.quarantine.evict":
            if row.get("replica") is not None:
                evictions[int(row["replica"])] += 1
    if not mass and not score:
        return []
    lines = ["## Per-replica health" if md else "Per-replica health", ""]
    if md:
        lines += ["| replica | mass first | mass last | score last "
                  "| evictions |", "|---:|---:|---:|---:|---:|"]
    for r in sorted(set(mass) | set(score)):
        m, s = mass.get(r), score.get(r)
        m_first = _fmt(m[0]) if m else "-"
        m_last = _fmt(m[-1]) if m else "-"
        s_last = _fmt(s[-1]) if s else "-"
        ev = evictions.get(r, 0)
        if md:
            lines.append(f"| {r} | {m_first} | {m_last} | {s_last} | {ev} |")
        else:
            lines.append(f"  replica {r}: mass {m_first} -> {m_last}  "
                         f"score last {s_last}  evictions {ev}")
    return lines


def _fleet_losses(rows: List[dict], md: bool) -> List[str]:
    """Per-scenario first -> last loss from the vector fleet.loss rows,
    grouped by fleet group label when present."""
    by_group: Dict[str, List[List[float]]] = defaultdict(list)
    for row in rows:
        if row.get("metric") == "fleet.loss":
            by_group[str(row.get("group", "0"))].append(
                _flatten(row["value"]))
    if not by_group:
        return []
    labels: Dict[str, List[str]] = {}
    for row in rows:
        if row.get("event") == "fleet.group":
            labels[str(row.get("group", "0"))] = row.get("scenarios") or []
    lines = ["## Fleet loss trajectories" if md else
             "Fleet loss trajectories", ""]
    for gid in sorted(by_group):
        steps = by_group[gid]
        names = labels.get(gid, [])
        n_scen = max(len(s) for s in steps)
        for i in range(n_scen):
            traj = [s[i] for s in steps if i < len(s)]
            name = names[i] if i < len(names) else f"scenario {i}"
            prefix = "- " if md else "  "
            lines.append(f"{prefix}group {gid} / {name}: "
                         f"loss {_fmt(traj[0])} -> {_fmt(traj[-1])} "
                         f"over {len(traj)} steps")
    return lines


def _request_summary(rows: List[dict], md: bool) -> List[str]:
    admits = [r for r in rows if r.get("event") == "serve.request.admit"]
    finishes = [r for r in rows if r.get("event") == "serve.request.finish"]
    if not admits and not finishes:
        return []
    lines = ["## Requests" if md else "Requests", ""]
    gen = sum(int(r.get("gen_tokens", 0) or 0) for r in finishes)
    prefix = "- " if md else "  "
    lines.append(f"{prefix}admitted {len(admits)}, finished {len(finishes)}, "
                 f"{gen} generated tokens")
    return lines


def _trace_summary(trace_doc: dict, md: bool) -> List[str]:
    events = trace_doc.get("traceEvents", [])
    if not events:
        return []
    by_name: Dict[str, List[float]] = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            by_name[ev["name"]].append(float(ev.get("dur", 0.0)))
    lines = ["## Trace spans" if md else "Trace spans", ""]
    if md:
        lines += ["| span | count | total ms | mean us |",
                  "|---|---:|---:|---:|"]
    for name in sorted(by_name):
        durs = by_name[name]
        total_ms = sum(durs) / 1e3
        mean_us = sum(durs) / len(durs)
        if md:
            lines.append(f"| `{name}` | {len(durs)} | {_fmt(total_ms)} | "
                         f"{_fmt(mean_us)} |")
        else:
            lines.append(f"  {name:<24} n={len(durs):<6} "
                         f"total={_fmt(total_ms)}ms mean={_fmt(mean_us)}us")
    return lines


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def render_summary(rows: List[dict], trace_doc: Optional[dict] = None,
                   fmt: str = "text", title: str = "obs run") -> str:
    """Render parsed metric rows (+ optional parsed trace doc) into a
    ``text`` or ``md`` report string."""
    if fmt not in ("text", "md"):
        raise ValueError(f"fmt must be 'text' or 'md', got {fmt!r}")
    md = fmt == "md"
    lines: List[str] = [f"# {title}" if md else f"== {title} =="]
    for section in (_metric_table(rows, md),
                    _request_summary(rows, md),
                    _replica_health(rows, md),
                    _quarantine_timeline(rows, md),
                    _fleet_losses(rows, md)):
        if section:
            lines.append("")
            lines.extend(section)
    if trace_doc is not None:
        section = _trace_summary(trace_doc, md)
        if section:
            lines.append("")
            lines.extend(section)
    if len(lines) == 1:
        lines += ["", "(no rows)"]
    return "\n".join(lines) + "\n"


def summarize_files(metrics_path: Union[str, Path],
                    trace_path: Optional[Union[str, Path]] = None,
                    fmt: str = "text") -> str:
    """Load a metrics JSONL (and optionally a trace JSON) and render the
    summary. The file-level twin of :func:`render_summary`."""
    rows = load_jsonl(metrics_path)
    trace_doc = None
    if trace_path is not None:
        trace_doc = json.loads(Path(trace_path).read_text())
    return render_summary(rows, trace_doc, fmt=fmt,
                          title=str(Path(metrics_path).name))
