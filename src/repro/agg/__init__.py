"""`repro.agg` — the unified, layout-polymorphic aggregator API.

    from repro import agg

    ctma = agg.resolve("ctma:gm@pallas", lam=0.25)
    d_hat = ctma(X, s)        # X: (m, d) matrix  -> fused Pallas kernels
    d_hat = ctma(tree, s)     # stacked pytree    -> leaf-wise global-pass path

The serving-side logit layout (``logits.py``) rides the same registry:
``resolve_logits(spec)`` votes an ``(R, S, V)`` per-token logit stack through
any rule, and ``staleness_weights`` derives the replicas' vote masses from
checkpoint lag the way the paper derives update weights from delay.

Spec grammar (``spec.py``): ``rule[:base][@backend]``. One registry
(``registry.py``) backs `core.engine`, `dist.steps`, the launchers, the
benchmarks and the examples; the legacy factories
(`core.aggregators.make_aggregator`, `kernels.ops.make_kernel_aggregator`,
`dist.robust.make_stacked_aggregator`) are deprecated shims over
:func:`resolve`.
"""
from .spec import AggregatorSpec, BACKENDS, parse  # noqa: F401
from .registry import (  # noqa: F401
    AGGREGATOR_SPECS,
    Rule,
    has_hier,
    register,
    resolve,
    rules,
)
from .baselines import stacked_zeno, weighted_zeno  # noqa: F401
from .logits import resolve_logits, staleness_weights  # noqa: F401
