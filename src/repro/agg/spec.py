"""Aggregator spec grammar and the parsed :class:`AggregatorSpec`.

One string names one aggregation pipeline:

    rule[:base][@backend]

    rule     — registered rule name: ``mean | cwmed | gm | cwtm | krum |
               ctma | bucketing | zeno`` (``repro.agg.registry`` is open —
               register more).
    base     — meta-rule composition: the inner rule a meta-aggregator wraps
               (``ctma:gm`` anchors ω-CTMA at the weighted geometric median;
               ``bucketing:cwmed`` aggregates bucket means with ω-CWMed).
    backend  — execution engine. For flat ``(m, d)`` inputs: ``jnp``
               (pure-XLA oracle), ``pallas`` (fused kernels; interpret mode
               off-TPU), or ``auto`` (default: pallas on TPU, jnp elsewhere).
               Stacked-pytree inputs take the leaf-wise path with its single
               global distance pass; under ``auto`` or ``hier`` that path is
               additionally mesh-aware — lowered inside a multi-pod
               ``mesh_context`` it becomes the hierarchical cross-pod variant
               (per-pod partial distance sums + an (m,)-sized ``lax.psum``
               over the ``pod`` axis; dist/hierarchy.py). ``hier`` pins the
               hierarchical wrapper — resolving it for a rule (or meta-rule
               anchor) without a cross-pod path raises rather than silently
               handing back a buffer-gathering one; ``jnp``/``pallas`` pin
               the single-host stacked path.

Examples: ``"cwmed"``, ``"ctma:gm@pallas"``, ``"ctma:cwmed@hier"``,
``"bucketing:cwmed@jnp"``.

Numeric parameters (``lam``, ``iters``, rule-specific extras like Krum's
``n_byz`` or Zeno's ``rho``) are carried on the spec, not in the string —
pass them to :func:`parse` / :func:`repro.agg.resolve` as keyword arguments.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

BACKENDS = ("auto", "jnp", "pallas", "hier")

DEFAULT_GM_ITERS = 32


class AggregatorSpec(NamedTuple):
    """Parsed, hashable description of one aggregation pipeline."""
    rule: str                               # registered rule name
    base: Optional[str] = None              # inner rule for meta-aggregators
    backend: str = "auto"                   # auto | jnp | pallas | hier
    lam: float = 0.0                        # λ: trimmed weight mass / band
    iters: int = DEFAULT_GM_ITERS           # Weiszfeld iterations (gm paths)
    interpret: Optional[bool] = None        # pallas interpret override (None=auto)
    params: Tuple[Tuple[str, object], ...] = ()  # sorted rule-specific extras

    @property
    def canonical(self) -> str:
        """The spec string this parses back from (backend kept if non-auto)."""
        s = self.rule if self.base is None else f"{self.rule}:{self.base}"
        return s if self.backend == "auto" else f"{s}@{self.backend}"

    @property
    def kwargs(self) -> dict:
        return dict(self.params)


SpecLike = Union[str, AggregatorSpec]


def parse(spec: SpecLike, *, lam: Optional[float] = None,
          iters: Optional[int] = None, backend: Optional[str] = None,
          interpret: Optional[bool] = None, **extra) -> AggregatorSpec:
    """Parse ``rule[:base][@backend]`` (or refine an existing spec).

    Keyword arguments override spec fields; a backend embedded in the string
    (``...@pallas``) takes precedence over the ``backend=`` keyword, so config
    strings can pin their engine while call sites supply a default.
    """
    if isinstance(spec, AggregatorSpec):
        out = spec
        if lam is not None:
            out = out._replace(lam=float(lam))
        if iters is not None:
            out = out._replace(iters=int(iters))
        if backend is not None and spec.backend == "auto":
            out = out._replace(backend=_check_backend(backend))
        if interpret is not None:
            out = out._replace(interpret=bool(interpret))
        if extra:
            merged = {**dict(out.params), **extra}
            out = out._replace(params=tuple(sorted(merged.items())))
        return out

    if not isinstance(spec, str) or not spec.strip():
        raise TypeError(f"aggregator spec must be a non-empty string or "
                        f"AggregatorSpec, got {spec!r}")
    body, sep, bk = spec.strip().lower().partition("@")
    rule, _, base = body.partition(":")
    if not rule:
        raise ValueError(f"malformed aggregator spec {spec!r} "
                         f"(grammar: rule[:base][@backend])")
    return AggregatorSpec(
        rule=rule,
        base=base or None,
        backend=_check_backend(bk if sep else (backend or "auto")),
        lam=float(lam) if lam is not None else 0.0,
        iters=int(iters) if iters is not None else DEFAULT_GM_ITERS,
        interpret=interpret,
        params=tuple(sorted(extra.items())),
    )


def _check_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise KeyError(f"unknown agg backend {backend!r}; "
                       f"choose from {' | '.join(BACKENDS)}")
    return backend
