"""Baseline rules from related work, as first-class registry specs.

``zeno`` — Zeno++-style descent scoring (Xie et al.), adapted to the paper's
weighted setting. Zeno++ scores a candidate update g against an oracle
gradient v by the estimated descent γ⟨v, g⟩ − ρ‖g‖² and suspends updates that
score low. No trusted validation gradient exists at the server here, so the
oracle proxy is the ROBUST anchor — the weighted coordinate-wise median of
the received updates (the same anchoring trick as ω-CTMA; the plain weighted
mean would be poisoned by the very rows being scored). Rows keep the top
(1 − λ) *weight mass* by score with CTMA's boundary-clipping trim, so the
kept mass is exactly (1 − λ)·Σs. ``bucketing`` (Karimireddy et al.) lives in
``core.aggregators``; both compose through the one registry.

Both layouts are covered: the flat ``(m, d)`` scorer below, and a stacked
variant whose inner-product/norm pass is computed ONCE GLOBALLY across the
pytree leaves — the same single-pass discipline as ``dist.robust``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray
Pytree = Any

_tmap = jax.tree_util.tree_map


def _zeno_combine_weights(score: Array, s: Array, lam: float):
    """Keep the top (1-λ) weight mass by score (largest first)."""
    from repro.kernels.wctma_fused import trim_weights  # pure jnp

    # trim_weights keeps the SMALLEST 'distances'; negate to keep top scores
    return trim_weights(-score, s, lam)


def weighted_zeno(x: Array, s: Optional[Array] = None, *, lam: float = 0.25,
                  rho: float = 1e-3, eps: float = 1e-8) -> Array:
    """Zeno++-style scoring on an (m, d) matrix with weights s."""
    from repro.core.aggregators import weighted_cwmed

    m = x.shape[0]
    s = jnp.ones((m,), jnp.float32) if s is None else s.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    v = weighted_cwmed(xf, s)                               # robust oracle proxy
    vnorm = jnp.sqrt(jnp.maximum(jnp.sum(jnp.square(v)), eps))
    score = (xf @ v) / vnorm - rho * jnp.sum(jnp.square(xf), axis=1)
    kept, thresh = _zeno_combine_weights(score, s, lam)
    return jnp.einsum("m,md->d", kept, xf) / jnp.maximum(thresh, 1e-30)


def stacked_zeno(tree: Pytree, s: Optional[Array] = None, *, lam: float = 0.25,
                 rho: float = 1e-3, eps: float = 1e-8) -> Pytree:
    """Zeno++-style scoring on a stacked pytree: the per-row ⟨v, x_i⟩ and
    ‖x_i‖² reductions are accumulated across ALL leaves in one pass."""
    from repro.dist.robust import _combine, _flat2, _lead, _weights, stacked_cwmed

    s = _weights(s, _lead(tree))
    v = stacked_cwmed(tree, s)                              # robust oracle proxy

    def part(xl, vl):
        xf = _flat2(xl).astype(jnp.float32)
        vf = vl.reshape(-1).astype(jnp.float32)
        return jnp.stack([xf @ vf, jnp.sum(jnp.square(xf), axis=1)])

    inner, norm2 = sum(jax.tree_util.tree_leaves(_tmap(part, tree, v)))
    vsq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(v))
    score = inner / jnp.sqrt(jnp.maximum(vsq, eps)) - rho * norm2
    kept, thresh = _zeno_combine_weights(score, s, lam)
    return _combine(tree, kept, jnp.maximum(thresh, 1e-30))
