"""One registry, one resolve path for every robust-aggregation rule.

``resolve(spec, **kw)`` returns a LAYOUT-POLYMORPHIC callable

    agg(X, s=None)      X: (m, d) matrix  -> (d,) vector
    agg(tree, s=None)   tree: stacked pytree, leaves (m, ...) -> pytree

dispatching per input layout:

    flat (m, d) matrix   backend ``jnp``    -> core.aggregators oracles
                         backend ``pallas`` -> kernels.ops fused pipelines
                         backend ``auto``   -> pallas on TPU, jnp elsewhere
    stacked pytree       the leaf-wise ``dist.robust`` path with its single
                         GLOBAL distance pass (no O(m·d) flatten copy); under
                         ``auto``/``hier`` it is additionally mesh-aware —
                         traced inside a multi-pod ``mesh_context`` the rule
                         runs the ``dist.hierarchy`` cross-pod variant
                         (per-pod partial distance sums + an (m,)-sized psum
                         over the ``pod`` axis; no momentum gather)

A rule without a native implementation for some path degrades gracefully:
missing pallas -> the jnp oracle; missing stacked -> a flatten/unflatten
fallback around the flat path (correct, but pays the copy the native stacked
rules avoid — fine for benchmark baselines, wrong for hot paths).

Registering a new rule (e.g. a baseline from related work) is one call:

    register("myrule", flat=lambda sp: my_flat_fn, stacked=..., pallas=...)

Each builder receives the parsed :class:`AggregatorSpec` (λ, iters, extra
params) and returns ``fn(x, s=None)`` for its layout.
"""
from __future__ import annotations

import inspect
import math
from functools import partial
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import aggregators as _flatagg

from .baselines import stacked_zeno, weighted_zeno
from .spec import AggregatorSpec, SpecLike, parse


def _ops():
    """Pallas kernel wrappers, imported ONLY when a pallas builder runs — the
    pure-jnp paths (core.engine with backend='jnp') never pay the kernel
    package import."""
    from repro.kernels import ops
    return ops


def _stk():
    """Stacked-pytree backends, imported ONLY when a stacked builder runs
    (first pytree input) — flat-matrix users never pull in repro.dist."""
    from repro.dist import robust
    return robust


def _hr():
    """Hierarchical cross-pod backends, imported lazily like ``_stk``."""
    from repro.dist import hierarchy
    return hierarchy


Builder = Callable[[AggregatorSpec], Callable]


class Rule(NamedTuple):
    flat: Builder                      # jnp oracle — always present
    pallas: Optional[Builder] = None   # fused kernel path (None -> flat)
    stacked: Optional[Builder] = None  # leaf-wise path (None -> flatten fallback)
    hier: Optional[Builder] = None     # cross-pod shard_map path (None -> stacked)
    composes: bool = False             # accepts a ':base' inner rule
    doc: str = ""


_RULES: Dict[str, Rule] = {}


def register(name: str, flat: Builder, *, pallas: Optional[Builder] = None,
             stacked: Optional[Builder] = None, hier: Optional[Builder] = None,
             composes: bool = False, doc: str = "") -> None:
    """Add (or override) a rule in the global registry."""
    _RULES[name.lower()] = Rule(flat, pallas, stacked, hier, composes, doc)


def rules() -> Dict[str, Rule]:
    return dict(_RULES)


def has_hier(spec: SpecLike, **kw) -> bool:
    """Whether ``spec`` resolves to a rule WITH a hierarchical cross-pod path
    (its stacked branch upgrades under a multi-pod ``mesh_context``). The
    launch layer keys the pod-sharded momentum layout and the dry-run's
    ``agg_hier`` artifact flag on this — a rule that would silently fall back
    to the single-host stacked path must not claim the hierarchical layout."""
    sp = parse(spec, **kw)
    if sp.backend not in ("auto", "hier"):
        return False  # an explicit @jnp/@pallas pin never upgrades
    rule = _RULES.get(sp.rule)
    if rule is None or rule.hier is None:
        return False
    return rule.hier(sp) is not None


def resolve(spec: SpecLike, **kw) -> Callable:
    """Parse ``spec`` and build its layout-polymorphic aggregator.

    ``resolve("ctma:gm@pallas", lam=0.25)(X_or_tree, s)`` — see module doc.
    The parsed spec is attached to the callable as ``.spec``.
    """
    sp = parse(spec, **kw)
    if sp.rule not in _RULES:
        raise KeyError(f"unknown aggregator rule {sp.rule!r} in spec "
                       f"{sp.canonical!r}; registered: {sorted(_RULES)}")
    rule = _RULES[sp.rule]
    if sp.base is not None:
        if not rule.composes:
            raise ValueError(f"rule {sp.rule!r} does not compose with a base "
                             f"(got {sp.canonical!r})")
        if sp.base not in _RULES:
            raise KeyError(f"unknown base rule {sp.base!r} in {sp.canonical!r}")

    backend = sp.backend
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "pallas" and rule.pallas is not None:
        flat_fn = rule.pallas(sp)
    else:
        flat_fn = rule.flat(sp)

    # The stacked branch builds lazily on the first pytree input: flat-only
    # users never import the dist layer, and a stacked builder that declines
    # (returns None — e.g. ctma over a base with no leaf-wise path) falls
    # back to the flatten adapter instead of handing out a broken callable.
    # Under ``auto``/``hier`` a rule with a hier builder gets the mesh-aware
    # dist.hierarchy wrapper, which itself falls back to the single-host
    # stacked path whenever no multi-pod mesh_context is active at trace time.
    # An EXPLICIT ``@hier`` pins that wrapper, so it must fail loudly (here,
    # eagerly) rather than silently hand back a path that would gather the
    # stacked buffers across pods.
    cache: dict = {}
    if sp.backend == "hier":
        hfn = rule.hier(sp) if rule.hier is not None else None
        if hfn is None:
            raise ValueError(
                f"spec {sp.canonical!r}: rule {sp.rule!r} has no hierarchical "
                f"cross-pod path for these parameters; use backend 'auto' for "
                f"graceful single-host fallback, or a rule registered with a "
                f"hier builder")
        cache["hier"] = hfn

    def _stacked_fn():
        if "fn" not in cache:
            fn = rule.stacked(sp) if rule.stacked is not None else None
            fn = fn if fn is not None else _flatten_fallback(flat_fn)
            hfn = cache.get("hier")
            if hfn is None and sp.backend == "auto" and rule.hier is not None:
                hfn = rule.hier(sp)
            if hfn is not None:
                fn = hfn
            cache["fn"] = fn
        return cache["fn"]

    def agg(x, s=None):
        # A pinned ``@hier`` takes the hierarchical wrapper even for a flat
        # (m, d) matrix — the single-leaf stacked case, same values — so the
        # no-cross-pod-gather guarantee is never silently dropped.
        if _is_flat_matrix(x) and sp.backend != "hier":
            return flat_fn(x, s)
        return _stacked_fn()(x, s)

    agg.spec = sp
    agg.__name__ = f"agg<{sp.canonical}>"
    return agg


# ---------------------------------------------------------------------------
# Layout dispatch + generic stacked fallback
# ---------------------------------------------------------------------------

def _is_flat_matrix(x) -> bool:
    """A single (m, d) array takes the flat path; anything else (dicts,
    tuples, or single arrays of other ranks) is a stacked tree. The 2-D
    single-array case is semantically unambiguous: leaf-wise aggregation of
    one (m, d) leaf equals flat aggregation of the matrix."""
    return hasattr(x, "ndim") and x.ndim == 2


def _flatten_fallback(flat_fn: Callable) -> Callable:
    """Stacked adapter for rules with no native leaf-wise path: concatenate
    the (m, ...) leaves into one (m, d) matrix, run the flat rule, unflatten.
    Costs the O(m·d) copy the native stacked rules avoid."""
    def agg(tree, s=None):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        m = leaves[0].shape[0]
        x = jnp.concatenate(
            [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)
        out = flat_fn(x, s)
        pieces, off = [], 0
        for l in leaves:
            n = math.prod(l.shape[1:])
            pieces.append(out[off:off + n].reshape(l.shape[1:]))
            off += n
        return jax.tree_util.tree_unflatten(treedef, pieces)

    return agg


# ---------------------------------------------------------------------------
# Built-in rules
# ---------------------------------------------------------------------------

def _interp(sp: AggregatorSpec) -> bool:
    """Pallas interpret mode: explicit override, else Mosaic only on TPU."""
    if sp.interpret is not None:
        return sp.interpret
    return jax.default_backend() != "tpu"


def _split_kwargs(kw: dict, fn: Callable) -> tuple[dict, dict]:
    """Partition spec extras into (accepted by ``fn``, rest). Composed specs
    carry parameters for BOTH the meta-rule and its base (``ctma:krum`` with
    ``n_byz``): the meta-rule keeps what its signature names, the base builder
    receives the remainder."""
    try:
        params = inspect.signature(fn).parameters.values()
        names = {p.name for p in params
                 if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)}
    except (TypeError, ValueError):  # pragma: no cover
        return kw, {}
    return ({k: v for k, v in kw.items() if k in names},
            {k: v for k, v in kw.items() if k not in names})


def _flat_base(sp: AggregatorSpec, default: str, extras: dict) -> Callable:
    name = sp.base or default
    return _RULES[name].flat(sp._replace(rule=name, base=None,
                                         params=tuple(sorted(extras.items()))))


def _stacked_base(sp: AggregatorSpec, default: str,
                  extras: dict) -> Optional[Callable]:
    name = sp.base or default
    entry = _RULES[name]
    if entry.stacked is None:
        return None
    return entry.stacked(sp._replace(rule=name, base=None,
                                     params=tuple(sorted(extras.items()))))


def _cwtm_lam(sp: AggregatorSpec) -> float:
    return max(sp.lam, 1e-3)  # λ=0 would retain everything: degenerate band


def _pallas_ctma(sp: AggregatorSpec) -> Callable:
    interp = _interp(sp)
    base = sp.base or "cwmed"
    if not sp.kwargs:  # base extras force the composable jnp path
        if base == "cwmed":
            return partial(_ops().wctma, lam=sp.lam, interpret=interp)
        if base == "gm":
            return partial(_ops().wctma_gm, lam=sp.lam, iters=sp.iters,
                           interpret=interp)
    return _flat_ctma(sp)  # other anchors: no fused pipeline, jnp oracle


def _flat_ctma(sp: AggregatorSpec) -> Callable:
    mine, rest = _split_kwargs(sp.kwargs, _flatagg.weighted_ctma)
    for reserved in ("x", "s", "lam", "base"):
        mine.pop(reserved, None)
    return partial(_flatagg.weighted_ctma, lam=sp.lam,
                   base=_flat_base(sp, "cwmed", rest), **mine)


def _stacked_ctma(sp: AggregatorSpec) -> Optional[Callable]:
    stk = _stk()
    mine, rest = _split_kwargs(sp.kwargs, stk.stacked_ctma)
    for reserved in ("tree", "s", "lam", "base"):
        mine.pop(reserved, None)
    base = _stacked_base(sp, "cwmed", rest)
    if base is None:
        return None
    return partial(stk.stacked_ctma, lam=sp.lam, base=base, **mine)


def _hier_ctma(sp: AggregatorSpec) -> Optional[Callable]:
    hr = _hr()
    base = sp.base or "cwmed"
    if base not in hr._BASE_BODIES:
        return None  # unsupported anchor: resolve falls back to plain stacked
    # Route the anchor's own parameters exactly like the stacked path does
    # (gm: iters/eps; cwtm: the shared λ); any extras this path does not
    # recognize mean PR-2 stacked semantics must win — decline.
    extras = dict(sp.kwargs)
    base_kw = {}
    if base == "gm":
        base_kw = {"iters": sp.iters, "eps": extras.pop("eps", 1e-8)}
    elif base == "cwtm":
        base_kw = {"lam": _cwtm_lam(sp)}
    if extras:
        return None
    return partial(hr.hier_ctma, lam=sp.lam, base=base, base_kw=base_kw)


def _flat_bucketing(sp: AggregatorSpec) -> Callable:
    mine, rest = _split_kwargs(sp.kwargs, _flatagg.bucketing)
    for reserved in ("x", "s", "inner"):  # composition comes from the spec
        mine.pop(reserved, None)
    return partial(_flatagg.bucketing,
                   inner=_flat_base(sp, "cwmed", rest), **mine)


def _register_builtins() -> None:
    register(
        "mean",
        flat=lambda sp: _flatagg.weighted_mean,
        pallas=lambda sp: partial(_ops().wmean, interpret=_interp(sp)),
        stacked=lambda sp: _stk().stacked_mean,
        hier=lambda sp: _hr().hier_mean,
        doc="weighted mean — non-robust baseline",
    )
    register(
        "cwmed",
        flat=lambda sp: _flatagg.weighted_cwmed,
        pallas=lambda sp: partial(_ops().wcwmed, interpret=_interp(sp)),
        stacked=lambda sp: _stk().stacked_cwmed,
        hier=lambda sp: _hr().hier_cwmed,
        doc="ω-CWMed — weighted coordinate-wise median (Lemma C.3)",
    )
    register(
        "gm",
        flat=lambda sp: partial(_flatagg.weighted_gm, iters=sp.iters,
                                **sp.kwargs),
        pallas=lambda sp: partial(_ops().wgm, iters=sp.iters,
                                  interpret=_interp(sp), **sp.kwargs),
        stacked=lambda sp: partial(_stk().stacked_gm, iters=sp.iters,
                                   **sp.kwargs),
        hier=lambda sp: partial(_hr().hier_gm, iters=sp.iters, **sp.kwargs),
        doc="ω-GM / ω-RFA — weighted geometric median (Lemma C.1)",
    )
    register(
        "cwtm",
        flat=lambda sp: partial(_flatagg.weighted_cwtm, lam=_cwtm_lam(sp)),
        stacked=lambda sp: partial(_stk().stacked_cwtm, lam=_cwtm_lam(sp)),
        hier=lambda sp: partial(_hr().hier_cwtm, lam=_cwtm_lam(sp)),
        doc="ω-CWTM — weighted coordinate-wise trimmed mean",
    )
    register(
        "krum",
        flat=lambda sp: partial(_flatagg.krum, **sp.kwargs),
        stacked=lambda sp: partial(_stk().stacked_krum, **sp.kwargs),
        hier=lambda sp: partial(_hr().hier_krum, **sp.kwargs),
        doc="Krum (Blanchard et al. 2017) — unweighted baseline",
    )
    register(
        "ctma",
        flat=_flat_ctma,
        pallas=_pallas_ctma,
        stacked=_stacked_ctma,
        hier=_hier_ctma,
        composes=True,
        doc="ω-CTMA (Alg. 1) — centered trimmed meta-aggregator over :base",
    )
    register(
        "bucketing",
        flat=_flat_bucketing,
        composes=True,
        doc="bucketing meta-rule (Karimireddy et al. 2020) over :base",
    )
    register(
        "zeno",
        flat=lambda sp: partial(weighted_zeno, lam=sp.lam, **sp.kwargs),
        stacked=lambda sp: partial(stacked_zeno, lam=sp.lam, **sp.kwargs),
        doc="Zeno++-style descent scoring (Xie et al.), weighted trim",
    )


_register_builtins()

# Every built-in spec the cross-backend parity suite sweeps.
AGGREGATOR_SPECS = ("mean", "cwmed", "gm", "cwtm", "krum",
                    "ctma:cwmed", "ctma:gm", "bucketing:cwmed", "zeno")
