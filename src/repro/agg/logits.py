"""Logit-layout entry point for the unified aggregator registry, plus the
staleness-derived vote weights of the replicated serving path.

Training aggregates an ``(m, d)`` update matrix once per server iteration;
replicated serving aggregates an ``(R, S, V)`` logit stack once per decoded
TOKEN — R replicas voting over S slots' vocab rows. :func:`resolve_logits`
adapts any registry spec (``rule[:base][@backend]``) to that layout by
vmapping the rule's flat ``(R, V)`` path over the slot axis, so the vote
inherits every weighted rule (ω-CWMed, ω-CTMA, ω-GM, zeno, ...) and backend
the training path has.

:func:`staleness_weights` maps per-replica checkpoint staleness to vote
masses exactly as the paper maps worker delay to update-count weights
``s_t^{(i)}``: a replica serving checkpoint version ``v = latest - lag`` has
absorbed ``v`` server updates, so its mass is ``s_r = latest - lag_r``
(floored to keep the most stale replica from vanishing from the weighted
statistics entirely). Equal lags therefore yield equal masses, and the vote
reduces to the unweighted rule.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from .registry import resolve
from .spec import SpecLike

Array = jnp.ndarray


def resolve_logits(spec: SpecLike, **kw) -> Callable:
    """Build ``vote(logits, s=None)`` for an ``(R, S, V)`` logit stack.

    ``logits`` carries one (V,)-row per replica per slot; ``s`` is the (R,)
    vote-mass vector (staleness weights, availability/quarantine-masked).
    Returns the (S, V) voted logits. The parsed spec rides on ``.spec``."""
    flat = resolve(spec, **kw)

    def vote(logits: Array, s: Optional[Array] = None) -> Array:
        return jax.vmap(lambda x: flat(x, s), in_axes=1, out_axes=0)(logits)

    vote.spec = flat.spec
    vote.__name__ = f"logit_vote<{flat.spec.canonical}>"
    return vote


def staleness_weights(lags: Union[Array, Sequence[float]],
                      latest_version: Optional[float] = None,
                      floor: float = 1e-3) -> Array:
    """Per-replica vote masses from checkpoint staleness (versions behind).

    ``s_r = max(latest_version - lag_r, floor)`` — the update-count weighting
    of the paper applied to checkpoints: fresher replicas carry more mass,
    identical lags carry identical mass. ``latest_version`` defaults to
    ``max(lags) + 1`` so the most stale replica still holds a unit mass and
    a fully fresh fleet (all lags zero) gets uniform unit masses."""
    lags = jnp.asarray(lags, jnp.float32)
    if latest_version is None:
        latest = jnp.max(lags) + 1.0
    else:
        latest = jnp.asarray(latest_version, jnp.float32)
    return jnp.maximum(latest - lags, floor)
