"""Runtime compile-count sentinel: pin "how many times did XLA compile?".

The repo's two perf keystones are compile-amortization contracts, not
numbers: the fleet runs ONE ``jit(vmap(step))`` per compile-signature group
(fleet/scenario.py), and the serve engine compiles one prefill per prompt
bucket plus one decode step (serve/engine.py warmup). Nothing enforced them
— a stray Python-int argument or a drifted bucket table silently
reintroduces per-call recompiles and only a benchmark notices. This module
makes the contract testable:

    with compile_count() as c:
        engine.run(requests, warmup=False)
    assert c.count == 0          # zero recompiles across the workload

Built on :mod:`jax.monitoring` duration events — every XLA backend compile
fires ``/jax/core/compile/backend_compile_duration``, while tracing-cache
hits fire only the trace event. Counting is process-global, so pin tests
must warm JAX's internal eager-op caches (a throwaway run of the same
shapes) before measuring deltas; ``c.events`` keeps the per-event log for
diagnosing which compile broke the pin.

Used by tests/test_lint_runtime.py to pin: one compile group per fleet
shape class, one compile per scheduler prompt bucket across a synthetic
workload, and zero recompiles across breakdown-bisection probes
(fleet/matrix.py ``run_cached``).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Iterator, List, Tuple

from jax import monitoring

# jax/_src/dispatch.py event names (stable across the 0.4.x line)
BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"


@dataclass
class CompileCounter:
    """Live tally of compile activity inside a :func:`compile_count` block.

    ``count`` is the number of XLA backend compiles (the expensive event a
    pin test cares about); ``traces`` counts jaxpr retraces (a superset —
    cache hits retrace without recompiling); ``events`` is the raw
    ``(event, seconds)`` log."""
    count: int = 0
    traces: int = 0
    events: List[Tuple[str, float]] = field(default_factory=list)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _active: bool = field(default=True, repr=False)

    def record(self, event: str, duration: float) -> None:
        if not self._active:
            return
        with self._lock:
            if event == BACKEND_COMPILE_EVENT:
                self.count += 1
            elif event == TRACE_EVENT:
                self.traces += 1
            self.events.append((event, duration))


def _unregister(callback) -> bool:
    """Best-effort removal of a duration listener (private API in 0.4.x;
    the counter deactivates itself regardless, so failure is benign)."""
    try:
        from jax._src import monitoring as _m
        _m._unregister_event_duration_listener_by_callback(callback)
        return True
    except Exception:
        return False


@contextmanager
def compile_count() -> Iterator[CompileCounter]:
    """Count XLA backend compiles (and retraces) within the block.

    Process-global: compiles triggered by other threads land in the same
    tally, and JAX's internal eager ops (``jnp.ones`` et al.) compile too on
    first use — warm them before pinning deltas."""
    counter = CompileCounter()

    def listener(event: str, duration: float, **_kw) -> None:
        counter.record(event, duration)

    monitoring.register_event_duration_secs_listener(listener)
    try:
        yield counter
    finally:
        counter._active = False
        _unregister(listener)


def warmup_eager_cache() -> None:
    """Compile the tiny eager ops pin tests would otherwise count.

    First use of ``jnp.ones``/``jnp.zeros``/``jnp.arange``/scalar casts each
    costs a backend compile of its own; running them once up front keeps a
    subsequent :func:`compile_count` block measuring only the compiles the
    code under test owns."""
    import jax.numpy as jnp

    ops = [jnp.ones(8), jnp.zeros(8), jnp.arange(8),
           jnp.asarray(1.0), jnp.asarray(1, jnp.int32)]
    for x in ops:
        x.block_until_ready()
