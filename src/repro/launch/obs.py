"""Obs CLI: validate and summarize an observed run's artifacts.

    # render a summary of a run's metrics (+ optional trace)
    PYTHONPATH=src python -m repro.launch.obs --metrics obs/serve.metrics.jsonl \
        --trace obs/serve.trace.json
    PYTHONPATH=src python -m repro.launch.obs --metrics ... --format md

    # CI schema gate: exit 1 if any artifact fails validation
    PYTHONPATH=src python -m repro.launch.obs --validate \
        --metrics obs/serve.metrics.jsonl --trace obs/serve.trace.json

``--metrics`` takes the JSONL a :class:`repro.obs.MetricSink` wrote;
``--trace`` the Chrome-trace JSON a :class:`repro.obs.Tracer` exported
(load it at https://ui.perfetto.dev). ``--validate`` checks the metrics
rows against the registry schema and the trace against the trace-event
shape instead of printing the summary. Produce the artifacts by passing
``--obs-dir`` to ``repro.launch.serve`` / ``repro.launch.fleet``.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--metrics", default="",
                    help="metrics JSONL written by a MetricSink")
    ap.add_argument("--trace", default="",
                    help="Chrome-trace JSON exported by a Tracer")
    ap.add_argument("--format", default="text", choices=("text", "md"),
                    dest="fmt", help="summary output format")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the artifacts instead of summarizing; "
                         "exit 1 on any error")
    args = ap.parse_args(argv)
    if not args.metrics and not args.trace:
        ap.error("need --metrics and/or --trace")

    from repro.obs import summarize_files, validate_jsonl, validate_trace

    if args.validate:
        errors = []
        if args.metrics:
            errors += validate_jsonl(args.metrics)
        if args.trace:
            errors += validate_trace(args.trace)
        for e in errors:
            print(f"ERROR: {e}", file=sys.stderr)
        checked = " + ".join(p for p in (args.metrics, args.trace) if p)
        if errors:
            print(f"{checked}: {len(errors)} schema error(s)",
                  file=sys.stderr)
            return 1
        print(f"{checked}: OK")
        return 0

    if args.metrics:
        print(summarize_files(args.metrics, args.trace or None,
                              fmt=args.fmt), end="")
    else:
        import json

        from repro.obs import render_summary
        doc = json.loads(open(args.trace).read())
        print(render_summary([], doc, fmt=args.fmt, title=args.trace),
              end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
