"""Fleet launcher: run an adversarial scenario grid from the command line.

    PYTHONPATH=src python -m repro.launch.fleet --smoke
    PYTHONPATH=src python -m repro.launch.fleet --problem classifier \
        --attacks sign_flip,adaptive_scale --aggs ctma:cwmed,cwmed \
        --arrivals proportional,squared --alphas inf,0.3 \
        --m 9 --byz-frac 0.22 --steps 100 --breakdown --json matrix.json

Builds the attack × aggregator × arrival × heterogeneity cross-product with
`repro.fleet.matrix_scenarios`, runs it through the batched vmapped engine
(`run_scenarios`), and — with ``--breakdown`` — bisects every cell's
breakdown point and times the resolved aggregators
(`repro.fleet.breakdown_matrix`). Prints one line per cell; ``--json`` dumps
the full structured rows. ``--smoke`` is the quadratic-family quick check.
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def _csv(s: str) -> list:
    return [x.strip() for x in s.split(",") if x.strip()]


def _alphas(s: str) -> tuple:
    return tuple(math.inf if a in ("inf", "iid") else float(a)
                 for a in _csv(s))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--problem", default="classifier",
                    choices=("classifier", "quadratic"))
    ap.add_argument("--attacks", default="sign_flip,little,empire,"
                                         "adaptive_scale")
    ap.add_argument("--aggs", default="ctma:cwmed,ctma:gm,cwmed")
    ap.add_argument("--arrivals", default="proportional,squared")
    ap.add_argument("--alphas", default="inf,0.3",
                    help="Dirichlet label-skew levels; 'inf' = IID")
    ap.add_argument("--m", type=int, default=9)
    ap.add_argument("--byz-frac", type=float, default=2.0 / 9.0)
    ap.add_argument("--lam", type=float, default=0.38)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seeds", default="0")
    ap.add_argument("--breakdown", action="store_true",
                    help="bisect each cell's breakdown point (slower)")
    ap.add_argument("--bisect-steps", type=int, default=0,
                    help="shorter horizon for breakdown probes (0 = full)")
    ap.add_argument("--json", default="", help="write structured rows here")
    ap.add_argument("--smoke", action="store_true",
                    help="quadratic family, short horizons")
    ap.add_argument("--obs-dir", default="",
                    help="write repro.obs telemetry here: "
                         "<dir>/fleet.metrics.jsonl + <dir>/fleet.trace.json "
                         "(per-scenario loss trajectories + engine.* device "
                         "metrics; summarize with python -m repro.launch.obs)")
    args = ap.parse_args(argv)

    from repro.fleet import (breakdown_matrix, matrix_scenarios,
                             run_scenarios)

    kw = dict(problem=args.problem, attacks=tuple(_csv(args.attacks)),
              aggs=tuple(_csv(args.aggs)),
              arrivals=tuple(_csv(args.arrivals)),
              alphas=_alphas(args.alphas), m=args.m, byz_frac=args.byz_frac,
              lam=args.lam, steps=args.steps, batch=args.batch,
              seeds=tuple(int(s) for s in _csv(args.seeds)))
    if args.smoke:
        kw.update(problem="quadratic", steps=min(args.steps, 60), batch=4)
    scenarios = matrix_scenarios(**kw)
    print(f"# {len(scenarios)} scenarios", file=sys.stderr)

    obs = None
    if args.obs_dir:
        from repro.obs import RunObs
        obs = RunObs.open(args.obs_dir, "fleet")

    if args.breakdown:
        rows = breakdown_matrix(scenarios,
                                bisect_steps=args.bisect_steps or None)
        for r in rows:
            acc = f" acc={r['acc']:.3f}" if "acc" in r else ""
            print(f"{r['cell']}: loss={r['final_loss']:.4f} "
                  f"(honest {r['honest_loss']:.4f}){acc} "
                  f"breakdown={r['breakdown_count']}/{r['m']} "
                  f"agg_us={r['agg_us_per_call']:.1f}")
    else:
        results = run_scenarios(scenarios, obs=obs)
        rows = []
        for res in results:
            ev = {k: float(v) for k, v in res.eval.items()}
            rows.append({"cell": res.scenario.label, **ev,
                         "lambda_emp": res.lambda_emp,
                         "engine_us_per_step": res.us_per_step})
            print(f"{res.scenario.label}: " +
                  " ".join(f"{k}={v:.4f}" for k, v in ev.items()) +
                  f" lambda={res.lambda_emp:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}", file=sys.stderr)
    if obs is not None:
        obs.close()
        print(f"# obs: wrote {args.obs_dir}/fleet.metrics.jsonl + "
              f"fleet.trace.json", file=sys.stderr)


if __name__ == "__main__":
    main()
