"""ShapeDtypeStruct stand-ins + shardings for every model input — the
zero-allocation interface used by the multi-pod dry-run."""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, InputShape
from repro.dist.sharding import batch_sharding, cache_sharding, param_sharding, replicated
from repro.dist.steps import RobustDPConfig, TrainState, init_train_state
from repro.models.config import ModelConfig
from repro.models.lm import init_cache, init_lm
from repro.optim.mu2sgd import OptConfig
from repro.launch.mesh import dp_axes

Pytree = Any


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.mode == "decode":
        return {"tokens": sds((B, 1), jnp.int32)}
    if cfg.frontend == "audio":
        out = {"frames": sds((B, S, cfg.d_model), dt)}
        if shape.mode == "train":
            out["labels"] = sds((B, S), jnp.int32)
        return out
    if cfg.frontend == "vision":
        S_text = S - cfg.n_patches
        out = {"patches": sds((B, cfg.n_patches, cfg.d_model), dt),
               "tokens": sds((B, S_text), jnp.int32)}
        if shape.mode == "train":
            out["labels"] = sds((B, S_text), jnp.int32)
        return out
    out = {"tokens": sds((B, S), jnp.int32)}
    if shape.mode == "train":
        out["labels"] = sds((B, S), jnp.int32)
    return out


def params_specs(cfg: ModelConfig) -> Pytree:
    return jax.eval_shape(partial(init_lm, cfg=cfg), jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, shape: InputShape) -> Pytree:
    return jax.eval_shape(partial(init_cache, cfg, shape.global_batch, shape.seq_len))


def serve_cache_specs(cfg: ModelConfig, n_slots: int, max_len: int) -> Pytree:
    """Zero-allocation specs for the repro.serve slot-mapped decode cache
    (batch dim = slots, per-slot (S,) pos vector — serve/cache.py). It shards
    like any decode cache: ``cache_sharding`` already treats axis 0 (axis 1
    under ``groups``) as the batch/slot axis, which is how ServeEngine pins
    its donated in-place layout on a mesh."""
    from repro.serve.cache import init_slot_cache
    return jax.eval_shape(partial(init_slot_cache, cfg, n_slots, max_len))


def train_state_specs(cfg: ModelConfig, opt_cfg: OptConfig,
                      robust: Optional[RobustDPConfig] = None) -> Pytree:
    return jax.eval_shape(
        lambda: init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0), robust))


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def _strip_axes(spec: P, banned: set) -> P:
    def clean(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a not in banned)
            return kept if kept else None
        return None if entry in banned else entry
    return P(*(clean(e) for e in spec))


def train_state_sharding(cfg: ModelConfig, mesh, state_shape: TrainState,
                         robust: Optional[RobustDPConfig] = None) -> TrainState:
    pshard = param_sharding(cfg, mesh, state_shape.opt.w)
    scalar = NamedSharding(mesh, P())

    def like_params(tree_shape):
        if tree_shape is None:
            return None
        return param_sharding(cfg, mesh, tree_shape)

    opt = state_shape.opt._replace(
        w=pshard,
        x=like_params(state_shape.opt.x),
        x_prev=like_params(state_shape.opt.x_prev),
        d=like_params(state_shape.opt.d),
        t=scalar,
        anchor=like_params(state_shape.opt.anchor),
    )
    D = None
    counts = None
    if state_shape.D is not None:
        from repro.agg import has_hier
        from repro.dist.hierarchy import pod_count
        if (pod_count(mesh) > 1 and robust is not None
                and has_hier(robust.agg, lam=robust.lam)):
            # multi-pod AND the rule actually takes the hierarchical path
            # (same predicate as the aggregation dispatch): pod-sharded
            # parameter dims, group axis local — the layout
            # dist/hierarchy.py's cross-pod distance psum reads in place
            # (no momentum gather over the pod axis). Rules without a hier
            # path keep the dp layout their stacked fallback expects.
            from repro.dist.sharding import hier_momentum_sharding
            D = hier_momentum_sharding(mesh, state_shape.D)
        else:
            dp = dp_axes(mesh)
            banned = set(dp)
            D = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, P(dp, *_strip_axes(s.spec, banned))),
                pshard)
        counts = NamedSharding(mesh, P())
    return TrainState(opt=opt, D=D, counts=counts)


def logits_sharding(cfg: ModelConfig, mesh, shape) -> NamedSharding:
    """(B, S, V): batch over dp, vocab over model (when divisible)."""
    from repro.dist.sharding import _fits
    dp = dp_axes(mesh)
    spec = [None, None, None]
    if _fits(shape[0], mesh, dp):
        spec[0] = dp
    if _fits(shape[-1], mesh, ("model",)):
        spec[-1] = "model"
    return NamedSharding(mesh, P(*spec))


def make_all_specs(cfg: ModelConfig, mesh, shape: InputShape, opt_cfg: OptConfig,
                   robust: Optional[RobustDPConfig] = None, with_out: bool = True):
    """Returns (arg_shapes, in_shardings, out_shardings) for the step kind.

    Output shardings are pinned to the input layouts (state/cache round-trip
    in place); without this XLA re-replicates the updated KV cache every step
    (§Perf iteration 1: a full cache all-gather per layer, per decoded token).
    """
    if shape.mode == "train":
        state_shape = train_state_specs(cfg, opt_cfg, robust)
        state_shard = train_state_sharding(cfg, mesh, state_shape, robust)
        b_shape = batch_specs(cfg, shape)
        b_shard = batch_sharding(cfg, mesh, b_shape)
        out = (state_shard, NamedSharding(mesh, P())) if with_out else None
        return (state_shape, b_shape), (state_shard, b_shard), out
    if shape.mode == "prefill":
        p_shape = params_specs(cfg)
        p_shard = param_sharding(cfg, mesh, p_shape)
        b_shape = batch_specs(cfg, shape)
        b_shard = batch_sharding(cfg, mesh, b_shape)
        out = None
        if with_out:
            c_shape = cache_specs(cfg, shape)
            c_shard = cache_sharding(cfg, mesh, c_shape)
            B, S = shape.global_batch, shape.seq_len
            S_out = S - (cfg.n_patches if cfg.frontend == "vision" else 0)
            lsh = logits_sharding(cfg, mesh, (B, S_out, cfg.vocab))
            out = (lsh, c_shard)
        return (p_shape, b_shape), (p_shard, b_shard), out
    # decode: weight-stationary contraction sharding (see dist/sharding.py)
    p_shape = params_specs(cfg)
    p_shard = param_sharding(cfg, mesh, p_shape, mode="decode")
    c_shape = cache_specs(cfg, shape)
    c_shard = cache_sharding(cfg, mesh, c_shape)
    b_shape = batch_specs(cfg, shape)
    b_shard = batch_sharding(cfg, mesh, b_shape)
    out = None
    if with_out:
        lsh = logits_sharding(cfg, mesh, (shape.global_batch, 1, cfg.vocab))
        out = (lsh, c_shard)
    return ((p_shape, c_shape, b_shape["tokens"]),
            (p_shard, c_shard, b_shard["tokens"]), out)
