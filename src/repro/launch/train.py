"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 50 --batch 8 --seq 128 [--robust] [--opt mu2|momentum|sgd]

Runs real steps on the available devices (CPU here; on TPU the same script
shards over the production mesh via --mesh). Checkpoints every
``--ckpt-every`` steps into --workdir.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.data import lm_batches
from repro.dist.steps import (RobustDPConfig, init_train_state, make_robust_train_step,
                              make_train_step)
from repro.optim.mu2sgd import OptConfig
from repro.utils import logger


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt", default="mu2", choices=["mu2", "momentum", "sgd"])
    ap.add_argument("--robust", action="store_true")
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--agg", default="ctma:cwmed",
                    help="repro.agg spec: rule[:base][@backend], e.g. "
                         "ctma:gm@pallas | cwmed | zeno")
    ap.add_argument("--lam", type=float, default=0.25)
    ap.add_argument("--byz-groups", type=int, default=0)
    ap.add_argument("--byz-attack", default="sign_flip")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptConfig(name=args.opt, lr=args.lr, gamma=0.1, beta=0.25)
    robust_cfg = None
    if args.robust:
        byz = tuple(range(args.byz_groups))
        robust_cfg = RobustDPConfig(n_groups=args.groups, agg=args.agg, lam=args.lam,
                                    byz_groups=byz, byz_attack=args.byz_attack
                                    if byz else "none")
        step_fn = make_robust_train_step(cfg, opt_cfg, robust_cfg)
    else:
        step_fn = make_train_step(cfg, opt_cfg)
    # donate the train state: w/x/D buffers update in place every step
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    state = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed), robust_cfg)
    data = lm_batches(cfg, args.batch, args.seq, seed=args.seed)

    losses = []
    t0 = time.time()
    for k in range(args.steps):
        state, metrics = step_fn(state, next(data))
        losses.append(float(metrics["loss"]))
        if args.log_every and (k + 1) % args.log_every == 0:
            logger.info("step %d/%d loss %.4f (%.2f s/step)", k + 1, args.steps,
                        losses[-1], (time.time() - t0) / (k + 1))
        if args.ckpt_every and args.workdir and (k + 1) % args.ckpt_every == 0:
            save_pytree(state.opt.w, Path(args.workdir) / "ckpt", k + 1)

    first = float(np.mean(losses[:5])) if len(losses) >= 5 else losses[0]
    last = float(np.mean(losses[-5:]))
    logger.info("done: loss %.4f -> %.4f over %d steps", first, last, args.steps)
    return {"first_loss": first, "last_loss": last, "losses": losses}


if __name__ == "__main__":
    main()
