"""Multi-pod dry-run: lower + compile every (architecture × input shape) on the
production meshes (16×16 single pod; 2×16×16 multi-pod) without allocating a
single parameter, and extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --robust

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>[__robust].json.

IMPORT TRAP: importing this module forces XLA_FLAGS to a 512-device host
platform BEFORE jax initializes — import nothing from here in code that
should see the real backend (the collective_bytes parser lives in
repro.utils for exactly this reason).
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable
from repro.dist.steps import (RobustDPConfig, make_prefill_step, make_robust_train_step,
                              make_serve_step, make_train_step)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_all_specs
from repro.optim.mu2sgd import OptConfig

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link (approx, per direction)

# The HLO collective parser lives in repro.utils (import-side-effect free;
# this module forces the placeholder device platform above). Re-exported here
# for back-compat with existing callers/tests.
from repro.utils import collective_bytes  # noqa: E402


def _sum_cost(ca) -> dict:
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes_accessed": byts}


def model_flops(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference (per step)."""
    from repro.models.lm import param_count
    n = param_count(cfg)
    if cfg.arch_type == "moe":
        d = cfg.d_model
        dense_moe = cfg.n_experts * 3 * d * cfg.d_expert
        active_moe = (cfg.top_k + cfg.n_shared) * 3 * d * cfg.d_expert
        n = n - cfg.n_layers * dense_moe + cfg.n_layers * active_moe
    sh = SHAPES[shape]
    tokens = sh.global_batch * (sh.seq_len if sh.mode != "decode" else 1)
    mult = 6 if sh.mode == "train" else 2
    base = mult * n * tokens
    if sh.mode == "train":
        base *= 1.5  # μ²-SGD evaluates the gradient at two points per sample
    return base


def build_step(cfg, shape, opt_cfg, robust_cfg):
    sh = SHAPES[shape]
    if sh.mode == "train":
        if robust_cfg is not None:
            return make_robust_train_step(cfg, opt_cfg, robust_cfg)
        return make_train_step(cfg, opt_cfg)
    if sh.mode == "prefill":
        return make_prefill_step(cfg, sh.seq_len)
    return make_serve_step(cfg)


def _compile_step(cfg, shape, opt_cfg, robust_cfg, mesh):
    sh = shape if isinstance(shape, SHAPES["train_4k"].__class__) else SHAPES[shape]
    step = build_step_cfg(cfg, sh, opt_cfg, robust_cfg)
    arg_shapes, arg_shardings, out_shardings = make_all_specs(
        cfg, mesh, sh, opt_cfg, robust_cfg)
    t0 = time.time()
    # serving donates the KV cache so the slice update is in-place (§Perf
    # iteration 3: without aliasing every layer rewrites its full cache).
    donate = (1,) if sh.mode == "decode" else ()
    from repro.dist.context import mesh_context
    with mesh, mesh_context(mesh):
        jitted = jax.jit(step, in_shardings=arg_shardings,
                         out_shardings=out_shardings, donate_argnums=donate)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def build_step_cfg(cfg, sh, opt_cfg, robust_cfg):
    if sh.mode == "train":
        if robust_cfg is not None:
            return make_robust_train_step(cfg, opt_cfg, robust_cfg)
        return make_train_step(cfg, opt_cfg)
    if sh.mode == "prefill":
        return make_prefill_step(cfg, sh.seq_len)
    return make_serve_step(cfg)


def _probe_costs(cfg, shape, opt_cfg, robust_cfg, mesh) -> dict:
    """Two-point depth extrapolation of per-device cost/collective terms.

    cost_analysis counts a lax.scan body once (trip counts are not applied),
    so the full scanned module undercounts. We instead compile the SAME
    architecture unrolled at n_layers = g and 2g (g = one repeating pattern
    group) and extrapolate linearly in depth — exact for homogeneous stacks,
    ≲3% for mixed patterns (the remainder layers are counted at the group
    mean). Validated against a fully-unrolled compile in tests.
    """
    g = len(cfg.pattern)
    c1cfg = cfg.with_(n_layers=g, scan_layers=False)
    c2cfg = cfg.with_(n_layers=2 * g, scan_layers=False)
    res = []
    for c in (c1cfg, c2cfg):
        compiled, _, _ = _compile_step(c, shape, opt_cfg, robust_cfg, mesh)
        cost = _sum_cost(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
        res.append({"flops": cost["flops"], "bytes": cost["bytes_accessed"],
                    "coll": coll["total"], "coll_by_kind": coll})
    L = cfg.n_layers
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_layer = max(res[1][k] - res[0][k], 0.0) / g
        out[k] = res[0][k] - g * per_layer + L * per_layer
        out[k + "_per_layer"] = per_layer
        out[k + "_base"] = res[0][k] - g * per_layer  # embed/head/opt overhead
    out["coll_by_kind_2g"] = res[1]["coll_by_kind"]
    return out


def dryrun_one(arch: str, shape: str, *, multi_pod: bool = False,
               robust: bool = False, agg: str = "ctma:cwmed",
               opt_name: str = "mu2",
               implicit_x_prev: bool = False, save: bool = True,
               verbose: bool = True, probe: bool = True,
               debug_mesh: bool = False, cfg_override=None) -> dict:
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    sh = SHAPES[shape]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = ("2x2x2" if multi_pod else "2x2") if debug_mesh else (
        "2x16x16" if multi_pod else "16x16")
    tag = f"{arch}__{shape}__{mesh_name}" + ("__robust" if robust else "")
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "status": "skip",
               "reason": reason}
        if verbose:
            print(f"[dryrun] SKIP {tag}: {reason}")
        if save:
            _save(tag, rec)
        return rec

    if debug_mesh:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 2, pod=2 if multi_pod else 0)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    opt_cfg = OptConfig(name=opt_name, lr=1e-3, gamma=0.1, beta=0.25,
                        implicit_x_prev=implicit_x_prev)
    robust_cfg = None
    if robust and sh.mode == "train":
        dp = n_chips // mesh.shape["model"]
        robust_cfg = RobustDPConfig(n_groups=min(dp, 32), agg=agg, lam=0.25)
    # On a multi-pod mesh the robust step's stacked aggregation auto-dispatches
    # (via mesh_context in _compile_step) to the dist.hierarchy cross-pod path
    # — IF the rule has one: pod-sharded momenta, distance reductions as
    # (m,)-sized psums over 'pod'. Rules without a hier path (zeno,
    # bucketing, ctma over unsupported anchors) fall back to the single-host
    # stacked lowering and must not claim a gather-free artifact.
    from repro.agg import has_hier
    from repro.dist.hierarchy import pod_count
    agg_hier = bool(robust_cfg is not None and pod_count(mesh) > 1
                    and has_hier(robust_cfg.agg, lam=robust_cfg.lam))

    # 1) FULL config lower+compile (scan mode) — the pass/fail gate; its
    #    memory_analysis sees the true full-model argument/temp footprint.
    compiled, t_lower, t_compile = _compile_step(cfg, shape, opt_cfg, robust_cfg, mesh)

    try:
        ma = compiled.memory_analysis()
        mem = {
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        mem["total_bytes_per_device"] = (mem["output_bytes"] + mem["temp_bytes"]
                                         + mem["argument_bytes"])
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    # 2) roofline terms from the depth-extrapolated unrolled probes
    #    (cost_analysis is per-device on the partitioned module; scan bodies
    #    are counted once, hence the probes — see _probe_costs).
    if probe:
        pc = _probe_costs(cfg, shape, opt_cfg, robust_cfg, mesh)
        cost = {"flops": pc["flops"], "bytes_accessed": pc["bytes"],
                "per_layer": {k: pc[k + "_per_layer"] for k in ("flops", "bytes", "coll")},
                "base": {k: pc[k + "_base"] for k in ("flops", "bytes", "coll")}}
        coll = {"total": pc["coll"], "by_kind_2g_probe": pc["coll_by_kind_2g"]}
    else:
        cost = _sum_cost(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())

    t_compute = cost["flops"] / PEAK_FLOPS
    t_memory = cost["bytes_accessed"] / HBM_BW
    t_coll = coll["total"] / ICI_BW
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "robust": robust,
        "agg": agg if robust_cfg is not None else None, "agg_hier": agg_hier,
        "status": "ok", "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": cost, "memory": mem, "collectives": coll,
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
            "bottleneck": max((("compute", t_compute), ("memory", t_memory),
                               ("collective", t_coll)), key=lambda kv: kv[1])[0],
        },
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / n_chips) / max(cost["flops"], 1.0),
    }
    if verbose:
        r = rec["roofline"]
        print(f"[dryrun] OK  {tag}: lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"compute {r['compute_s']*1e3:.2f}ms memory {r['memory_s']*1e3:.2f}ms "
              f"collective {r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}-bound | "
              f"args {mem.get('argument_bytes', 0)/2**30:.2f}GiB/dev"
              + (" | agg=hier" if agg_hier else ""))
    if save:
        _save(tag, rec)
    return rec


def _save(tag: str, rec: dict) -> None:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    (ART_DIR / f"{tag}.json").write_text(json.dumps(rec, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--robust", action="store_true")
    ap.add_argument("--agg", default="ctma:cwmed",
                    help="repro.agg spec for --robust: rule[:base][@backend]")
    ap.add_argument("--opt", default="mu2")
    ap.add_argument("--implicit-x-prev", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="tiny 2x2 / 2x2x2 mesh for integration tests")
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args()

    combos = []
    archs = list(ARCH_NAMES) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in combos:
        try:
            rec = dryrun_one(a, s, multi_pod=mp, robust=args.robust,
                             agg=args.agg,
                             opt_name=args.opt, implicit_x_prev=args.implicit_x_prev,
                             debug_mesh=args.debug_mesh, probe=not args.no_probe,
                             save=not args.debug_mesh)
            if rec["status"] == "ok":
                n_ok += 1
            else:
                n_skip += 1
        except Exception as e:
            n_fail += 1
            print(f"[dryrun] FAIL {a} {s} multi_pod={mp}: {type(e).__name__}: {e}")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
