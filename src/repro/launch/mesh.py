"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: one pod = 16x16 = 256 chips; multi-pod = 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, *, pod: int = 0):
    """Small mesh for subprocess integration tests (host platform devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' folds into data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
