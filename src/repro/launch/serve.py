"""Batched decoding service loop (single-host demo of the serve path).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 4 --prompt-len 32 --gen 16

Prefills a batch of synthetic prompts and decodes greedily with the same
``serve_step`` the decode dry-run shapes lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.dist.steps import make_prefill_step, make_serve_step
from repro.models.lm import init_lm
from repro.utils import logger


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    max_len = args.prompt_len + args.gen
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    prefill_step = jax.jit(make_prefill_step(cfg, max_len))
    # donate the KV cache so the per-token slice update is in-place
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(args.seed)
    B = args.requests
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt_len)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_model)),
                                       jnp.dtype(cfg.dtype))

    t0 = time.time()
    logits, cache = prefill_step(params, batch)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(args.gen - 1):
        logits, cache = serve_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    jax.block_until_ready(gen)
    dt = time.time() - t0
    tput = B * args.gen / dt
    logger.info("served %d requests × %d tokens in %.2fs (%.1f tok/s)",
                B, args.gen, dt, tput)
    return {"tokens": np.asarray(gen), "tok_per_s": tput}


if __name__ == "__main__":
    main()
