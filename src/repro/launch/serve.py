"""Serving launcher: continuous-batching (repro.serve) vs static fixed-batch
decode, under a Poisson arrival process with heterogeneous prompt/generation
lengths.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 16 --engine both --rate 50 --gen-max 32

The continuous engine defaults to CHUNKED prefill through the unified
ragged step (two jit compiles total); ``--bucketed`` restores the legacy
bucketed prefill → insert → decode trio for A/B comparisons, and
``--chunk-size`` / ``--chunk-rows`` set the per-tick prefill token budget.

``--paged`` swaps the dense slot cache for the block-table paged KV cache
(``--page-size`` rows per page, ``--pages`` physical pool pages; 0 sizes the
pool at dense-equivalent capacity), so cache HBM scales with actual request
lengths and admission is page-budgeted — see serve/README.md for the layout
and memory accounting.

``--replicas R`` switches to the Byzantine-tolerant replicated engine
(``repro.serve.replicated``): R decode replicas vote every token through the
``--vote`` rule with staleness-derived weights (``--lags``), while
``--byz-replicas`` + ``--attack`` inject corrupted logits and
``--dead`` / ``--hang`` model availability faults; per-replica health and
quarantine events are logged after the run.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --replicas 3 --byz-replicas 2 --attack sign_flip --requests 8

Timings are reported split into compile (jit warmup), prefill and decode —
the old single tokens/s figure folded all three together (including compile
time) and is kept as ``combined_tok_s`` for back-compat.
"""
from __future__ import annotations

import argparse
import copy

import jax

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.core.attacks import LOGIT_ATTACKS, LogitAttackConfig
from repro.models.lm import init_lm
from repro.serve import (ReplicatedConfig, ReplicatedServeEngine, ServeConfig,
                         ServeEngine, synth_workload)
from repro.utils import logger


def _csv_ints(text: str):
    return tuple(int(x) for x in text.split(",")) if text else ()


def _log_report(rep) -> None:
    mode = (f"chunked({rep.chunk_size})" if rep.chunked else "bucketed")
    logger.info(
        "[%s/%s] %d reqs | compile %.2fs | prefill %.3fs (%.0f tok/s) | "
        "decode %.3fs (%.0f tok/s, occupancy %.2f) | combined %.1f tok/s | "
        "ttft p50 %.3fs p99 %.3fs | latency p50 %.3fs p99 %.3fs",
        rep.engine, mode, rep.n_requests, rep.compile_s, rep.prefill_s,
        rep.prefill_tok_s, rep.decode_s, rep.decode_tok_s,
        rep.mean_occupancy, rep.combined_tok_s, rep.ttft_p50_s,
        rep.ttft_p99_s, rep.latency_p50_s, rep.latency_p99_s)
    if rep.paged:
        logger.info(
        "[%s] paged cache: %d pages x %d rows | page occupancy %.2f | "
        "%.1f pages/request",
        rep.engine, rep.n_pages, rep.page_size, rep.mean_page_occupancy,
        rep.mean_pages_per_req)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "static", "both"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prefill-batch", type=int, default=4)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=32)
    ap.add_argument("--gen-min", type=int, default=4)
    ap.add_argument("--gen-max", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at t=0")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bucketed", action="store_true",
                    help="legacy bucketed-prefill trio instead of the "
                         "default chunked unified step")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="prefill chunk width (tokens); 0 = page size if "
                         "--paged else 16")
    ap.add_argument("--chunk-rows", type=int, default=1,
                    help="max prefill chunk rows per mixed tick")
    ap.add_argument("--paged", action="store_true",
                    help="block-table paged KV cache (serve/cache.py)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV rows per page (with --paged)")
    ap.add_argument("--pages", type=int, default=0,
                    help="physical pool pages; 0 = dense-equivalent capacity")
    # Byzantine-tolerant replicated serving (repro.serve.replicated)
    ap.add_argument("--replicas", type=int, default=0,
                    help="decode replicas voting each token; 0 = single engine")
    ap.add_argument("--byz-replicas", default="",
                    help="comma-separated Byzantine replica ids (e.g. 2 or 1,2)")
    ap.add_argument("--attack", default="none", choices=list(LOGIT_ATTACKS),
                    help="logit attack the Byzantine replicas transmit")
    ap.add_argument("--lags", default="",
                    help="comma-separated per-replica checkpoint staleness "
                         "(versions behind); empty = all fresh")
    ap.add_argument("--vote", default="cwmed",
                    help="repro.agg spec for the per-token logit vote")
    ap.add_argument("--dead", default="",
                    help="comma-separated replica ids that stop responding")
    ap.add_argument("--hang", default="",
                    help="comma-separated replica ids that intermittently stall")
    ap.add_argument("--obs-dir", default="",
                    help="write repro.obs telemetry here: "
                         "<dir>/serve.metrics.jsonl + <dir>/serve.trace.json "
                         "(Perfetto-loadable; summarize with "
                         "python -m repro.launch.obs)")
    ap.add_argument("--no-device-metrics", action="store_true",
                    help="with --obs-dir: host-side spans/rows only, keep "
                         "the jitted steps' uninstrumented HLO")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    extra = cfg.n_patches if cfg.frontend == "vision" else 0
    max_len = extra + args.prompt_max + args.gen_max
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)

    workload = synth_workload(
        args.requests, cfg.vocab, seed=args.seed,
        prompt_lens=(args.prompt_min, args.prompt_max),
        gen_lens=(args.gen_min, args.gen_max), rate=args.rate,
        n_patches=extra, d_model=cfg.d_model if extra else 0)

    scfg = ServeConfig(
        n_slots=args.slots, max_len=max_len,
        max_prefill_batch=args.prefill_batch,
        temperature=args.temperature, top_k=args.top_k,
        eos_id=args.eos_id, seed=args.seed,
        chunked=not args.bucketed, chunk_size=args.chunk_size,
        chunk_rows=args.chunk_rows,
        paged=args.paged, page_size=args.page_size, n_pages=args.pages)

    engines = (["continuous", "static"] if args.engine == "both"
               else [args.engine])
    rcfg = None
    if args.replicas > 0:
        rcfg = ReplicatedConfig(
            n_replicas=args.replicas, vote=args.vote,
            attack=LogitAttackConfig(name=args.attack),
            byz=_csv_ints(args.byz_replicas), lags=_csv_ints(args.lags),
            dead=_csv_ints(args.dead), hang=_csv_ints(args.hang),
            attack_seed=args.seed)
    obs = None
    if args.obs_dir:
        from repro.obs import RunObs
        obs = RunObs.open(args.obs_dir, "serve",
                          device_metrics=not args.no_device_metrics)
    reports = {}
    for name in engines:
        reqs = [copy.deepcopy(r) for r in workload]
        if rcfg is not None:
            rep = ReplicatedServeEngine(cfg, params, scfg, rcfg,
                                        engine=name, obs=obs).run(reqs)
        else:
            rep = ServeEngine(cfg, params, scfg, engine=name,
                              obs=obs).run(reqs)
        _log_report(rep)
        if rcfg is not None:
            for h in rep.replicas:
                logger.info(
                    "[%s] replica %d (%s, lag %.0f, mass %.2f): voted %d | "
                    "missed %d | divergent %d | evictions %d | score %.3f",
                    name, h["replica"], h["role"], h["lag"], h["weight"],
                    h["tokens_voted"], h["tokens_missed"],
                    h["divergent_tokens"], h["evictions"], h["mean_score"])
            if rep.quarantine_events:
                logger.info("[%s] quarantine events: %s (first at decode "
                            "step %s)", name, rep.quarantine_events,
                            rep.first_quarantine_step)
        reports[name] = rep
    if len(reports) == 2:
        c, s = reports["continuous"], reports["static"]
        if s.decode_tok_s > 0:
            logger.info("continuous/static decode speedup: %.2fx",
                        c.decode_tok_s / s.decode_tok_s)

    if obs is not None:
        obs.close()
        logger.info("obs: wrote %s/serve.metrics.jsonl + serve.trace.json",
                    args.obs_dir)

    rep = reports[engines[0]]
    return {"reports": {k: v.as_dict() for k, v in reports.items()},
            "outputs": rep.outputs, "tok_per_s": rep.combined_tok_s}


if __name__ == "__main__":
    main()
