"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block (state-space duality).

One program per (batch, chunk). The chunk-local recurrence is evaluated in its
dual quadratic "masked attention" form — three MXU matmuls over (c × c) and
(c × n) tiles that live entirely in VMEM — and the kernel additionally emits
the chunk's outgoing state contribution. The O(nc) inter-chunk linear
recurrence (tiny) stays in XLA (`ops.ssd_scan`), mirroring
`repro.models.ssm.ssd_chunked` exactly.

Block sizing: c=chunk, h heads, p head_dim, n state. VMEM working set is
c·h·p (x, y) + h·c² (decay mask) + h·p·n (state) floats — e.g. c=64, h=8
per-program slabs keep everything under ~4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)        # (c, h, p)
    dt = dt_ref[0].astype(jnp.float32)      # (c, h)
    A = a_ref[...].astype(jnp.float32)      # (1, h)
    Bm = b_ref[0].astype(jnp.float32)       # (c, n)
    Cm = c_ref[0].astype(jnp.float32)       # (c, n)
    c, h, p = x.shape

    a = dt * A                              # (c, h) log-decay per step (<0)
    xb = x * dt[..., None]                  # discretized input
    a_hc = a.T                              # (h, c)
    a_cum = jnp.cumsum(a_hc, axis=-1)       # (h, c)

    # decay mask L[h, i, j] = exp(sum_{j<k<=i} a_k), lower-triangular
    seg = a_cum[:, :, None] - a_cum[:, None, :] + a_hc[:, None, :] * 0.0
    seg = a_cum[:, :, None] - a_cum[:, None, :]
    tri = jnp.tril(jnp.ones((c, c), jnp.float32))
    L = jnp.exp(jnp.where(tri[None] > 0, seg, -jnp.inf))

    scores = Cm @ Bm.T                      # (c, c)
    y = jnp.einsum("ij,hij,jhp->ihp", scores, L, xb)
    y_ref[0] = y

    decay_states = jnp.exp(a_cum[:, -1:] - a_cum)          # (h, c)
    s_ref[0] = jnp.einsum("cn,hc,chp->hpn", Bm, decay_states, xb)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_pallas(x, dt, A, Bm, Cm, *, chunk: int, interpret: bool = True):
    """Intra-chunk SSD. x: (b, s, h, p); dt: (b, s, h); A: (h,);
    Bm/Cm: (b, s, n). s must divide by `chunk`.
    Returns (y_diag (b, s, h, p), states (b, nc, h, p, n), chunk_decay (b, nc, h))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    c = chunk
    assert s % c == 0
    nc = s // c
    xc = x.reshape(b * nc, c, h, p)
    dtc = dt.reshape(b * nc, c, h)
    Bc = Bm.reshape(b * nc, c, n)
    Cc = Cm.reshape(b * nc, c, n)

    y, states = pl.pallas_call(
        _kernel,
        grid=(b * nc,),
        in_specs=[
            pl.BlockSpec((1, c, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, c, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
            pl.BlockSpec((1, c, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nc, c, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b * nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, A[None, :], Bc, Cc)

    a = (dt * A[None, None, :]).reshape(b, nc, c, h)
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))               # (b, nc, h)
    return (y.reshape(b, s, h, p), states.reshape(b, nc, h, p, n), chunk_decay)
