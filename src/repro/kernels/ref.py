"""Pure-jnp oracles for every Pallas kernel (the dry-run/production jnp path).

These are the ground truth the kernels are swept against in
tests/test_kernels.py, and simply delegate to the library reference
implementations so kernel == library semantics by construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregators import (weighted_ctma, weighted_cwmed, weighted_gm,
                                    weighted_mean)
from repro.models.config import ModelConfig
from repro.models.layers import _sdpa
from repro.models.ssm import ssd_chunked


def wcwmed_ref(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return weighted_cwmed(x.astype(jnp.float32), s.astype(jnp.float32))


def sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    d = x.astype(jnp.float32) - y.astype(jnp.float32)[None]
    return jnp.sum(jnp.square(d), axis=1)


def wcomb_ref(x: jnp.ndarray, coef: jnp.ndarray, denom) -> jnp.ndarray:
    return jnp.einsum("m,md->d", coef.astype(jnp.float32),
                      x.astype(jnp.float32)) / denom


def wgm_ref(x: jnp.ndarray, s: jnp.ndarray, iters: int = 8) -> jnp.ndarray:
    return weighted_gm(x.astype(jnp.float32), s.astype(jnp.float32), iters=iters)


def wctma_ref(x: jnp.ndarray, s: jnp.ndarray, lam: float) -> jnp.ndarray:
    return weighted_ctma(x.astype(jnp.float32), s.astype(jnp.float32), lam=lam)


def swa_decode_ref(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                   pos: jnp.ndarray, *, local: bool) -> jnp.ndarray:
    """Mirror of models.layers.attention_decode's masked SDPA (post-rope).

    ``pos`` may be a scalar (shared depth) or a (B,) vector (per-slot)."""
    B, H, hd = q.shape
    W = k_cache.shape[1]
    idx = jnp.arange(W)
    pos = jnp.asarray(pos)
    pb = pos[:, None] if pos.ndim else pos            # (B, 1) | ()
    if local:
        valid = (idx <= pb % W) | (pb >= W)
    else:
        valid = idx <= pb
    mask = (valid[:, None, None, :] if pos.ndim
            else valid[None, None, None, :])
    cfg = ModelConfig(n_heads=H, n_kv=k_cache.shape[2], head_dim=hd)
    out = _sdpa(cfg, q[:, None], k_cache, v_cache, mask)
    return out.reshape(B, H, hd).astype(jnp.float32)


def paged_decode_ref(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                     page_table: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Paged decode oracle: gather each slot's pages into a dense per-slot KV
    via the block table, then the same causal-prefix masked SDPA. q: (S, H,
    hd); pools (n_pages + 1, P, KV, hd); page_table (≥S, pps); pos (S,)."""
    S, H, hd = q.shape
    _, P, KV, _ = k_pool.shape
    pages = page_table[:S]                             # (S, pps)
    kg = k_pool[pages].reshape(S, -1, KV, hd)          # (S, pps*P, KV, hd)
    vg = v_pool[pages].reshape(S, -1, KV, hd)
    valid = jnp.arange(kg.shape[1])[None, :] <= pos[:, None]
    cfg = ModelConfig(n_heads=H, n_kv=KV, head_dim=hd)
    out = _sdpa(cfg, q[:, None], kg, vg, valid[:, None, None, :])
    return out.reshape(S, H, hd).astype(jnp.float32)


def ragged_paged_decode_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                            v_pool: jnp.ndarray, page_table: jnp.ndarray,
                            cu_q_lens: jnp.ndarray, q_lens: jnp.ndarray,
                            kv_lens: jnp.ndarray) -> jnp.ndarray:
    """Ragged paged-attention oracle over a mixed prefill-chunk/decode batch.

    q: (T, H, hd) — packed query tokens for Rn rows; row ``s`` owns tokens
    ``[cu_q_lens[s], cu_q_lens[s] + q_lens[s])`` (decode rows are q_len=1
    chunks); tokens between ``cu_q_lens[s] + q_lens[s]`` and
    ``cu_q_lens[s+1]`` are padding and come back zeroed. k/v_pool:
    (n_pages + 1, P, KV, hd) page pools (last page = dump); page_table:
    (Rn, pps) int32 physical pages per row; kv_lens: (Rn,) total context
    length per row AFTER this chunk (so token ``i`` of row ``s`` sits at
    absolute position ``kv_lens[s] - q_lens[s] + i`` and attends the causal
    prefix up to itself). Requires ``q_lens[s] <= cu_q_lens[s+1] -
    cu_q_lens[s]`` and ``q_lens[s] <= kv_lens[s] <= pps * P``."""
    T, H, hd = q.shape
    _, P, KV, _ = k_pool.shape
    Rn = page_table.shape[0]
    t_idx = jnp.arange(T)
    sid = jnp.clip(jnp.searchsorted(cu_q_lens, t_idx, side="right") - 1,
                   0, Rn - 1)
    off = t_idx - cu_q_lens[sid]
    in_seq = off < q_lens[sid]
    abs_pos = kv_lens[sid] - q_lens[sid] + off          # (T,)
    kg = k_pool[page_table[sid]].reshape(T, -1, KV, hd)  # (T, pps*P, KV, hd)
    vg = v_pool[page_table[sid]].reshape(T, -1, KV, hd)
    idx = jnp.arange(kg.shape[1])
    valid = in_seq[:, None] & (idx[None, :] <= abs_pos[:, None])
    cfg = ModelConfig(n_heads=H, n_kv=KV, head_dim=hd)
    out = _sdpa(cfg, q[:, None], kg, vg, valid[:, None, None, :])
    out = out.reshape(T, H, hd).astype(jnp.float32)
    return jnp.where(in_seq[:, None, None], out, 0.0)


def ssd_ref(x, dt, A, Bm, Cm, chunk):
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)
