"""Pure-jnp oracles for every Pallas kernel (the dry-run/production jnp path).

These are the ground truth the kernels are swept against in
tests/test_kernels.py, and simply delegate to the library reference
implementations so kernel == library semantics by construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregators import (weighted_ctma, weighted_cwmed, weighted_gm,
                                    weighted_mean)
from repro.models.config import ModelConfig
from repro.models.layers import _sdpa
from repro.models.ssm import ssd_chunked


def wcwmed_ref(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return weighted_cwmed(x.astype(jnp.float32), s.astype(jnp.float32))


def sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    d = x.astype(jnp.float32) - y.astype(jnp.float32)[None]
    return jnp.sum(jnp.square(d), axis=1)


def wcomb_ref(x: jnp.ndarray, coef: jnp.ndarray, denom) -> jnp.ndarray:
    return jnp.einsum("m,md->d", coef.astype(jnp.float32),
                      x.astype(jnp.float32)) / denom


def wgm_ref(x: jnp.ndarray, s: jnp.ndarray, iters: int = 8) -> jnp.ndarray:
    return weighted_gm(x.astype(jnp.float32), s.astype(jnp.float32), iters=iters)


def wctma_ref(x: jnp.ndarray, s: jnp.ndarray, lam: float) -> jnp.ndarray:
    return weighted_ctma(x.astype(jnp.float32), s.astype(jnp.float32), lam=lam)


def swa_decode_ref(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                   pos: jnp.ndarray, *, local: bool) -> jnp.ndarray:
    """Mirror of models.layers.attention_decode's masked SDPA (post-rope)."""
    B, H, hd = q.shape
    W = k_cache.shape[1]
    idx = jnp.arange(W)
    if local:
        valid = (idx <= pos % W) | (pos >= W)
    else:
        valid = idx <= pos
    cfg = ModelConfig(n_heads=H, n_kv=k_cache.shape[2], head_dim=hd)
    out = _sdpa(cfg, q[:, None], k_cache, v_cache, valid[None, None, None, :])
    return out.reshape(B, H, hd).astype(jnp.float32)


def ssd_ref(x, dt, A, Bm, Cm, chunk):
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)
