"""Shared d-axis padding for the (m, d) aggregation kernels.

Every weighted-aggregation kernel tiles the coordinate axis into ``block_d``
columns, which requires d to be a multiple of the tile. Previously each
``pallas_call`` wrapper (`wcwmed_pallas`, `sqdist_pallas`, `wcomb_pallas`)
padded its own copy of X — an extra O(m·d) HBM copy *per kernel launch* in the
multi-kernel ω-CTMA / Weiszfeld pipelines. The fused paths pad once here and
hand the padded matrix to every pass.

Zero-padding is semantics-preserving for all three kernels: the weighted
median of an all-zero column is 0, so padded coordinates contribute
(x - y)² = 0 to distance accumulations and 0 to weighted combinations.
"""
from __future__ import annotations

import jax.numpy as jnp


def pad_cols(x: jnp.ndarray, block_d: int) -> tuple[jnp.ndarray, int, int]:
    """Pad the last axis of ``x`` up to a multiple of ``block_d`` with zeros.

    Returns ``(padded, d, bd)`` where ``d`` is the original size and ``bd`` the
    effective tile (``min(block_d, d)``). No copy is made when d already tiles.
    """
    d = x.shape[-1]
    bd = min(block_d, d)
    pad = (-d) % bd
    x = x.astype(jnp.float32)
    if pad:
        width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, width)
    return x, d, bd
