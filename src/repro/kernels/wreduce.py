"""Pallas TPU kernels shared by ω-GM (Weiszfeld) and ω-CTMA:

- ``sqdist``: per-worker squared distances to an anchor, Σ_d (x_id - y_d)²,
  accumulated across d-tiles into an (m,) output (TPU grids execute
  sequentially, so revisiting the same output block is the canonical
  reduction pattern).
- ``wcomb``: weighted combination Σ_i c_i x_i / z over d-tiles — the Weiszfeld
  re-weighted average and the CTMA trimmed mean are both this matvec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 1024


def _sqdist_kernel(x_ref, y_ref, o_ref):
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)     # (m, bd)
    y = y_ref[...].astype(jnp.float32)     # (1, bd)
    part = jnp.sum(jnp.square(x - y), axis=1, keepdims=True)  # (m, 1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def sqdist_pallas(x: jnp.ndarray, y: jnp.ndarray, *, block_d: int = DEFAULT_BLOCK_D,
                  interpret: bool = True) -> jnp.ndarray:
    """x: (m, d), y: (d,) -> (m,) squared distances (float32)."""
    m, d = x.shape
    bd = min(block_d, d)
    pad = (-d) % bd
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, pad),))[None, :]
    out = pl.pallas_call(
        _sqdist_kernel,
        grid=((d + pad) // bd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda j: (0, j)),
            pl.BlockSpec((1, bd), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:, 0]


def _wcomb_kernel(x_ref, c_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)     # (m, bd)
    c = c_ref[...].astype(jnp.float32)     # (m, 1)
    o_ref[...] = jnp.sum(c * x, axis=0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def wcomb_pallas(x: jnp.ndarray, coef: jnp.ndarray, denom, *,
                 block_d: int = DEFAULT_BLOCK_D, interpret: bool = True) -> jnp.ndarray:
    """Σ_i coef_i x_i / denom. x: (m, d), coef: (m,) -> (d,)."""
    m, d = x.shape
    bd = min(block_d, d)
    pad = (-d) % bd
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _wcomb_kernel,
        grid=((d + pad) // bd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda j: (0, j)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((d + pad,), jnp.float32),
        interpret=interpret,
    )(xp, coef.astype(jnp.float32)[:, None])
    return out[:d] / denom
