"""Pallas TPU kernels shared by ω-GM (Weiszfeld) and ω-CTMA:

- ``sqdist``: per-worker squared distances to an anchor, Σ_d (x_id - y_d)²,
  accumulated across d-tiles into an (m,) output (TPU grids execute
  sequentially, so revisiting the same output block is the canonical
  reduction pattern).
- ``wcomb``: weighted combination Σ_i c_i x_i / z over d-tiles — the Weiszfeld
  re-weighted average and the CTMA trimmed mean are both this matvec.
- ``gm_step``: ONE fused Weiszfeld iteration (distance pass + 1/dist
  re-weighting + weighted combine) as a single two-phase ``pallas_call`` —
  the body of the ``lax.fori_loop`` in ``ops.wgm``. Phase 0 sweeps the
  d-tiles accumulating squared distances; phase 1 re-sweeps them emitting the
  re-weighted average, reading the finished (m, 1) distance accumulator from
  VMEM. One launch and zero host round-trips per iteration, vs two launches
  plus an (m,) device→trace round-trip for the unfused pipeline.

All wrappers take a pre-padded (m, dp) float32 matrix via the ``*_padded``
entry points (see pad.py — pad once, launch many) with thin padding wrappers
kept for standalone use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pad import pad_cols

DEFAULT_BLOCK_D = 1024


# ---------------------------------------------------------------------------
# sqdist
# ---------------------------------------------------------------------------

def _sqdist_kernel(x_ref, y_ref, o_ref):
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)     # (m, bd)
    y = y_ref[...].astype(jnp.float32)     # (1, bd)
    part = jnp.sum(jnp.square(x - y), axis=1, keepdims=True)  # (m, 1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def sqdist_padded(xp: jnp.ndarray, yp: jnp.ndarray, bd: int, *,
                  interpret: bool = True) -> jnp.ndarray:
    """xp: (m, dp) pre-padded, yp: (dp,) -> (m,) squared distances."""
    m, dp = xp.shape
    out = pl.pallas_call(
        _sqdist_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda j: (0, j)),
            pl.BlockSpec((1, bd), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(xp, yp.astype(jnp.float32)[None, :])
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def sqdist_pallas(x: jnp.ndarray, y: jnp.ndarray, *, block_d: int = DEFAULT_BLOCK_D,
                  interpret: bool = True) -> jnp.ndarray:
    """x: (m, d), y: (d,) -> (m,) squared distances (float32)."""
    xp, d, bd = pad_cols(x, block_d)
    yp, _, _ = pad_cols(y, bd)
    return sqdist_padded(xp, yp, bd, interpret=interpret)


# ---------------------------------------------------------------------------
# wcomb
# ---------------------------------------------------------------------------

def _wcomb_kernel(x_ref, c_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)     # (m, bd)
    c = c_ref[...].astype(jnp.float32)     # (m, 1)
    o_ref[...] = jnp.sum(c * x, axis=0)


def wcomb_padded(xp: jnp.ndarray, coef: jnp.ndarray, denom, bd: int, *,
                 interpret: bool = True) -> jnp.ndarray:
    """Σ_i coef_i xp_i / denom over a pre-padded (m, dp) matrix -> (dp,)."""
    m, dp = xp.shape
    out = pl.pallas_call(
        _wcomb_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda j: (0, j)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(xp, coef.astype(jnp.float32)[:, None])
    return out / denom


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def wcomb_pallas(x: jnp.ndarray, coef: jnp.ndarray, denom, *,
                 block_d: int = DEFAULT_BLOCK_D, interpret: bool = True) -> jnp.ndarray:
    """Σ_i coef_i x_i / denom. x: (m, d), coef: (m,) -> (d,)."""
    xp, d, bd = pad_cols(x, block_d)
    return wcomb_padded(xp, coef, denom, bd, interpret=interpret)[:d]


# ---------------------------------------------------------------------------
# fused Weiszfeld step (dist + reweight + combine in one launch)
# ---------------------------------------------------------------------------

def _gm_step_kernel(x_ref, s_ref, y_ref, o_ref, dist_ref, *, eps: float):
    phase = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)     # (m, bd)

    @pl.when(phase == 0)
    def _accumulate():
        y = y_ref[...].astype(jnp.float32)  # (1, bd)
        part = jnp.sum(jnp.square(x - y), axis=1, keepdims=True)

        @pl.when(j == 0)
        def _init():
            dist_ref[...] = jnp.zeros_like(dist_ref)

        dist_ref[...] += part

    @pl.when(phase == 1)
    def _combine():
        s = s_ref[...].astype(jnp.float32)  # (m, 1)
        dist = jnp.sqrt(jnp.maximum(dist_ref[...], 0.0))
        invd = s / jnp.maximum(dist, eps)   # (m, 1)
        o_ref[...] = jnp.sum(invd * x, axis=0) / jnp.sum(invd)


def gm_step_padded(xp: jnp.ndarray, s: jnp.ndarray, y: jnp.ndarray, bd: int, *,
                   eps: float = 1e-8, interpret: bool = True) -> jnp.ndarray:
    """One Weiszfeld iteration y -> Σ_i (s_i/‖x_i-y‖) x_i / Σ_i (s_i/‖x_i-y‖).

    xp: (m, dp) pre-padded, y: (dp,) -> (dp,). Shape-stable, so it is the
    body of ``lax.fori_loop`` in ops.wgm (traced ONCE regardless of iters).
    """
    m, dp = xp.shape
    y_new, _ = pl.pallas_call(
        functools.partial(_gm_step_kernel, eps=eps),
        grid=(2, dp // bd),
        in_specs=[
            pl.BlockSpec((m, bd), lambda p, j: (0, j)),
            pl.BlockSpec((m, 1), lambda p, j: (0, 0)),
            pl.BlockSpec((1, bd), lambda p, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bd,), lambda p, j: (j,)),
            pl.BlockSpec((m, 1), lambda p, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, s.astype(jnp.float32)[:, None], y.astype(jnp.float32)[None, :])
    return y_new
