"""Pallas TPU kernel: flash attention for single-token decode over a
(sliding-window) KV cache.

Grid = (batch·kv_head, cache_blocks). The KV cache streams through VMEM one
(bw, hd) block per grid step while the online-softmax state (running max,
denominator, accumulator) lives in VMEM scratch that persists across the
sequential TPU grid — the working set is O(G·hd + bw·hd) regardless of cache
length. This is the long_500k decode hot loop for gemma-style local layers
and recurrentgemma attention blocks.

Ring-buffer semantics: slot validity is derived from the absolute position
``pos`` exactly as in the reference (`repro.models.layers.attention_decode`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 256


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, W: int, bw: int, local: bool):
    c = pl.program_id(1)
    nc = pl.num_programs(1)
    q = q_ref[0].astype(jnp.float32)                  # (G, hd)
    hd = q.shape[-1]
    pos = pos_ref[0]
    scale = hd ** -0.5

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k = k_ref[0].astype(jnp.float32)                  # (bw, hd)
    v = v_ref[0].astype(jnp.float32)
    scores = (q @ k.T) * scale                        # (G, bw)
    idx = c * bw + jax.lax.iota(jnp.int32, bw)
    if local:
        valid = (idx <= pos % W) | (pos >= W)         # ring buffer occupancy
    else:
        valid = idx <= pos                            # causal prefix
    scores = jnp.where(valid[None, :], scores, -1e30)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(c == nc - 1)
    def _finish():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("local", "block_w", "interpret"))
def swa_decode_pallas(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                      pos: jnp.ndarray, *, local: bool, block_w: int = DEFAULT_BLOCK_W,
                      interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, hd); k/v_cache: (B, W, KV, hd); pos: () int32 -> (B, H, hd).

    Keys/values are assumed already rotary-embedded (cache layout identical to
    the reference decode path)."""
    B, H, hd = q.shape
    _, W, KV, _ = k_cache.shape
    G = H // KV
    bw = min(block_w, W)
    assert W % bw == 0, "cache length must divide the block"
    qg = q.reshape(B * KV, G, hd)
    kg = jnp.moveaxis(k_cache, 2, 1).reshape(B * KV, W, hd)
    vg = jnp.moveaxis(v_cache, 2, 1).reshape(B * KV, W, hd)
    pos_arr = jnp.broadcast_to(pos.astype(jnp.int32), (1,))

    out = pl.pallas_call(
        functools.partial(_kernel, W=W, bw=bw, local=local),
        grid=(B * KV, W // bw),
        in_specs=[
            pl.BlockSpec((1,), lambda g, c: (0,)),
            pl.BlockSpec((1, G, hd), lambda g, c: (g, 0, 0)),
            pl.BlockSpec((1, bw, hd), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, bw, hd), lambda g, c: (g, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda g, c: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max
            pltpu.VMEM((G, 1), jnp.float32),   # running denominator
            pltpu.VMEM((G, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(pos_arr, qg, kg, vg)
    return out.reshape(B, H, hd)
