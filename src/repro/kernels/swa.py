"""Pallas TPU kernels: flash attention for single-token decode over a
(sliding-window) KV cache — dense per-slot and paged (block-table) variants.

Dense (``swa_decode_pallas``): grid = (batch·kv_head, cache_blocks). The KV
cache streams through VMEM one (bw, hd) block per grid step while the
online-softmax state (running max, denominator, accumulator) lives in VMEM
scratch that persists across the sequential TPU grid — the working set is
O(G·hd + bw·hd) regardless of cache length. ``pos`` may be a scalar (classic
batched decode) or a (B,) vector (slot-mapped serving: every row decodes at
its own absolute depth). This is the long_500k decode hot loop for
gemma-style local layers and recurrentgemma attention blocks.

Paged (``paged_decode_pallas``): grid = (slot·kv_head, pages-of-that-slot).
The KV lives in a fixed page pool ``(n_pages + 1, page_size, KV, hd)`` and a
per-slot block table maps logical pages to physical ones; the table and the
per-slot ``pos`` ride in as scalar-prefetch arguments so the BlockSpec index
map can gather each slot's next physical page for DMA (vLLM-style paged
attention). The online-softmax scratch is carried across the sequential page
axis exactly as in the dense kernel. Unallocated logical pages point at the
pool's last (dump) page; their positions exceed ``pos`` and are masked out.

Ring-buffer semantics (dense, ``local=True``): slot validity is derived from
the absolute position ``pos`` exactly as in the reference
(`repro.models.layers.attention_decode`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 256


def _flash_step(step, q, k, v, valid, o_ref, m_ref, l_ref, acc_ref):
    """One online-softmax block step, shared by the dense and paged kernels.

    ``step`` is the sequential block index (init at 0, emit at the last —
    the TPU grid revisits the same scratch across it); ``valid`` masks this
    block's key columns. q: (G, hd) f32; k/v: (bk, hd) f32."""
    nsteps = pl.num_programs(1)
    scale = q.shape[-1] ** -0.5

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    scores = (q @ k.T) * scale                        # (G, bk)
    scores = jnp.where(valid[None, :], scores, -1e30)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(step == nsteps - 1)
    def _finish():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, W: int, bw: int, local: bool):
    c = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (G, hd)
    pos = pos_ref[0]
    k = k_ref[0].astype(jnp.float32)                  # (bw, hd)
    v = v_ref[0].astype(jnp.float32)
    idx = c * bw + jax.lax.iota(jnp.int32, bw)
    if local:
        valid = (idx <= pos % W) | (pos >= W)         # ring buffer occupancy
    else:
        valid = idx <= pos                            # causal prefix
    _flash_step(c, q, k, v, valid, o_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("local", "block_w", "interpret"))
def swa_decode_pallas(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                      pos: jnp.ndarray, *, local: bool, block_w: int = DEFAULT_BLOCK_W,
                      interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, hd); k/v_cache: (B, W, KV, hd); pos: () or (B,) int32
    -> (B, H, hd).

    A scalar ``pos`` is the classic shared-depth batched decode; a (B,)
    vector is the slot-mapped serving form — each batch row attends at its
    own absolute position (the BlockSpec index map routes row b's pos to all
    of its kv-head grid rows). Keys/values are assumed already
    rotary-embedded (cache layout identical to the reference decode path)."""
    B, H, hd = q.shape
    _, W, KV, _ = k_cache.shape
    G = H // KV
    bw = min(block_w, W)
    assert W % bw == 0, "cache length must divide the block"
    qg = q.reshape(B * KV, G, hd)
    kg = jnp.moveaxis(k_cache, 2, 1).reshape(B * KV, W, hd)
    vg = jnp.moveaxis(v_cache, 2, 1).reshape(B * KV, W, hd)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    out = pl.pallas_call(
        functools.partial(_kernel, W=W, bw=bw, local=local),
        grid=(B * KV, W // bw),
        in_specs=[
            pl.BlockSpec((1,), lambda g, c: (g // KV,)),
            pl.BlockSpec((1, G, hd), lambda g, c: (g, 0, 0)),
            pl.BlockSpec((1, bw, hd), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, bw, hd), lambda g, c: (g, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda g, c: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max
            pltpu.VMEM((G, 1), jnp.float32),   # running denominator
            pltpu.VMEM((G, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(pos_arr, qg, kg, vg)
    return out.reshape(B, H, hd)


def _paged_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, P: int, KV: int):
    g = pl.program_id(0)
    j = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (G, hd)
    pos = pos_ref[g // KV]
    k = k_ref[0, :, 0].astype(jnp.float32)            # (P, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    idx = j * P + jax.lax.iota(jnp.int32, P)
    valid = idx <= pos                                # causal prefix
    _flash_step(j, q, k, v, valid, o_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, page_table: jnp.ndarray,
                        pos: jnp.ndarray, *, interpret: bool = True
                        ) -> jnp.ndarray:
    """Per-slot paged flash decode for global (causal-prefix) layers.

    q: (S, H, hd); k/v_pool: (n_pages + 1, P, KV, hd) — physical page pools
    whose LAST page is the dump page; page_table: (≥S, pages_per_slot) int32
    mapping each slot's logical pages to physical ones (unallocated entries
    point at the dump page); pos: (S,) int32 per-slot absolute position.
    Returns (S, H, hd) float32.

    The table and pos are scalar-prefetch operands: the k/v BlockSpec index
    maps read ``page_table[slot, j]`` to choose which physical page block to
    stream next, so the kernel touches exactly the pages the block table
    names. Positions past ``pos`` (including every row of an unallocated /
    dump page) are masked in the online softmax."""
    S, H, hd = q.shape
    _, P, KV, _ = k_pool.shape
    G = H // KV
    pps = page_table.shape[1]
    qg = q.reshape(S * KV, G, hd)
    tbl = jnp.asarray(page_table, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32)

    def page_map(g, j, tbl_ref, pos_ref):
        return (tbl_ref[g // KV, j], 0, g % KV, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S * KV, pps),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda g, j, t, p: (g, 0, 0)),
            pl.BlockSpec((1, P, 1, hd), page_map),
            pl.BlockSpec((1, P, 1, hd), page_map),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda g, j, t, p: (g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max
            pltpu.VMEM((G, 1), jnp.float32),   # running denominator
            pltpu.VMEM((G, hd), jnp.float32),  # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, P=P, KV=KV),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S * KV, G, hd), jnp.float32),
        interpret=interpret,
    )(tbl, pos_arr, qg, k_pool, v_pool)
    return out.reshape(S, H, hd)


def _ragged_kernel(cu_ref, ql_ref, kvl_ref, tbl_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, acc_ref, *, P: int, KV: int):
    """Online softmax over a ragged mixed batch, one (row, page) per step.

    Unlike ``_flash_step``, ``p`` is masked explicitly: a grid step streams a
    page belonging to row ``s`` while the T-token query block spans EVERY
    row, so whole query rows are routinely all-masked here. With the
    unmasked ``exp(scores - m_new)`` idiom those rows would contribute
    ``exp(-1e30 - (-1e30)) = 1`` per key and corrupt the accumulator."""
    s = pl.program_id(0)
    j = pl.program_id(1)
    step = s * pl.num_programs(1) + j
    T, H, hd = q_ref.shape
    G = H // KV
    scale = hd ** -0.5

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32).reshape(T, KV, G, hd)
    k = k_ref[0].astype(jnp.float32)                   # (P, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    t = jax.lax.broadcasted_iota(jnp.int32, (T, 1), 0)
    start, qlen, kvlen = cu_ref[s], ql_ref[s], kvl_ref[s]
    in_seq = (t >= start) & (t < start + qlen)
    abs_pos = kvlen - qlen + (t - start)               # (T, 1)
    key_idx = j * P + jax.lax.broadcasted_iota(jnp.int32, (1, P), 1)
    valid = in_seq & (key_idx <= abs_pos)              # (T, P)
    vmask = valid[:, None, None, :]                    # (T, 1, 1, P)

    scores = jnp.einsum("tkgd,pkd->tkgp", q, k) * scale
    scores = jnp.where(vmask, scores, -1e30)
    m_prev = m_ref[...].reshape(T, KV, G)
    l_prev = l_ref[...].reshape(T, KV, G)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    p = jnp.where(vmask, jnp.exp(scores - m_new[..., None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc = acc_ref[...].reshape(T, KV, G, hd)
    acc = alpha[..., None] * acc + jnp.einsum("tkgp,pkd->tkgd", p, v)
    m_ref[...] = m_new.reshape(T, H)
    l_ref[...] = l_new.reshape(T, H)
    acc_ref[...] = acc.reshape(T, H, hd)

    @pl.when(step == pl.num_programs(0) * pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30).reshape(T, H, 1)
        o_ref[...] = acc_ref[...] / l


@functools.partial(jax.jit, static_argnames=("interpret",))
def ragged_paged_decode_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray, page_table: jnp.ndarray,
                               cu_q_lens: jnp.ndarray, q_lens: jnp.ndarray,
                               kv_lens: jnp.ndarray, *,
                               interpret: bool = True) -> jnp.ndarray:
    """Ragged paged flash attention over a mixed prefill-chunk/decode batch.

    q: (T, H, hd) packed query tokens — row ``s`` of the batch owns tokens
    ``[cu_q_lens[s], cu_q_lens[s] + q_lens[s])`` (decode rows are q_len=1
    chunks, prefill chunks longer runs; the gap up to ``cu_q_lens[s+1]`` is
    padding and returns zeros). k/v_pool: (n_pages + 1, P, KV, hd) page
    pools (last page = dump); page_table: (Rn, pps) int32; kv_lens: (Rn,)
    per-row context length AFTER the chunk, so token ``i`` of row ``s``
    attends the causal prefix of ``kv_lens[s] - q_lens[s] + i``.

    grid = (rows, pages): the row's next physical page streams through VMEM
    via the scalar-prefetched block table while the q block (all T tokens)
    stays VMEM-resident; the online-softmax scratch (m, l, acc over the full
    token block) is carried across the whole linearized grid, with per-step
    validity = "token belongs to this row AND key precedes it". Semantics
    match :func:`repro.kernels.ref.ragged_paged_decode_ref`."""
    T, H, hd = q.shape
    _, P, KV, _ = k_pool.shape
    Rn, pps = page_table.shape

    def ragged_page_map(s, j, cu, ql, kvl, tbl):
        return (tbl[s, j], 0, 0, 0)

    def ragged_whole_map(s, j, cu, ql, kvl, tbl):
        return (0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(Rn, pps),
        in_specs=[
            pl.BlockSpec((T, H, hd), ragged_whole_map),
            pl.BlockSpec((1, P, KV, hd), ragged_page_map),
            pl.BlockSpec((1, P, KV, hd), ragged_page_map),
        ],
        out_specs=pl.BlockSpec((T, H, hd), ragged_whole_map),
        scratch_shapes=[
            pltpu.VMEM((T, H), jnp.float32),       # running max
            pltpu.VMEM((T, H), jnp.float32),       # running denominator
            pltpu.VMEM((T, H, hd), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_kernel, P=P, KV=KV),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, H, hd), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(cu_q_lens, jnp.int32), jnp.asarray(q_lens, jnp.int32),
      jnp.asarray(kv_lens, jnp.int32), jnp.asarray(page_table, jnp.int32),
      q, k_pool, v_pool)
