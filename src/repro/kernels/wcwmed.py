"""Pallas TPU kernel: weighted coordinate-wise median by rank selection.

GPU implementations sort the m worker values per coordinate. On TPU,
data-dependent sorts map poorly onto the VPU; for the small worker counts of
robust aggregation (m ≤ 64) we instead compute each element's *weighted rank*
with dense masked reductions (an O(m²)-compare schedule that is branch-free
and tiles cleanly into VMEM):

    below_j = Σ_i s_i · [ (x_i, i) ≺ (x_j, j) ]        (strict lexicographic)
    median  = the unique j with below_j ≤ S/2 < below_j + s_j

with the paper's exact-tie rule (a prefix hitting S/2 exactly averages the
two adjacent elements) handled by two extra masked sums.

Layout: grid over d-tiles; each program holds an (m, bd) tile of X plus the
(m,) weights in VMEM and unrolls the m accumulation steps. The tile-local
selection body lives in ``wmed_tile`` so the fused ω-CTMA kernel
(``wctma_fused.py``) can piggyback its distance pass on the same VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pad import pad_cols

DEFAULT_BLOCK_D = 512


def wmed_tile(x: jnp.ndarray, s: jnp.ndarray, m: int) -> jnp.ndarray:
    """Weighted median of each column of an (m, bd) VMEM tile. s: (m, 1)."""
    total = jnp.sum(s)
    half = 0.5 * total

    below = jnp.zeros_like(x)
    for i in range(m):                           # unrolled: m is small & static
        xi = x[i][None, :]                       # (1, bd)
        si = s[i]
        lt = (xi < x)
        eq = (xi == x)
        idx_lt = jnp.full((m, 1), float(i)) < jnp.arange(m, dtype=jnp.float32)[:, None]
        below = below + si * ((lt | (eq & idx_lt)).astype(jnp.float32))

    cum = below + s                              # inclusive cumulative weight
    sel = (below <= half) & (cum > half)
    med = jnp.sum(jnp.where(sel, x, 0.0), axis=0)

    # exact-tie handling: some j with cum == half -> average with the next element
    tie_at = (cum == half)
    has_tie = jnp.any(tie_at, axis=0)
    v_tie = jnp.sum(jnp.where(tie_at, x, 0.0), axis=0)
    nxt = (below == half)
    v_next = jnp.sum(jnp.where(nxt, x, 0.0), axis=0)
    return jnp.where(has_tie, 0.5 * (v_tie + v_next), med)


def _kernel(x_ref, s_ref, o_ref, *, m: int):
    x = x_ref[...].astype(jnp.float32)          # (m, bd)
    s = s_ref[...].astype(jnp.float32)          # (m, 1)
    o_ref[...] = wmed_tile(x, s, m)


def wcwmed_padded(xp: jnp.ndarray, s: jnp.ndarray, bd: int, *,
                  interpret: bool = True) -> jnp.ndarray:
    """Median over a pre-padded float32 (m, dp) matrix -> (dp,). See pad.py."""
    m, dp = xp.shape
    return pl.pallas_call(
        functools.partial(_kernel, m=m),
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda j: (0, j)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(xp, s.astype(jnp.float32)[:, None])


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def wcwmed_pallas(x: jnp.ndarray, s: jnp.ndarray, *, block_d: int = DEFAULT_BLOCK_D,
                  interpret: bool = True) -> jnp.ndarray:
    """x: (m, d), s: (m,) -> (d,) float32."""
    xp, d, bd = pad_cols(x, block_d)
    return wcwmed_padded(xp, s, bd, interpret=interpret)[:d]
