"""Fused ω-CTMA (paper Algorithm 1) — single-pass anchor + distances.

The unfused pipeline makes ≥3 full HBM passes over the (m, d) update matrix:

    pass 1  wcwmed_pallas   X -> anchor                (reads X)
    pass 2  sqdist_pallas   X, anchor -> distances     (reads X again)
    pass 3  wcomb_pallas    X, kept -> trimmed mean    (reads X again)

Remark 4.1's O(dm) cost model assumes the aggregator is bandwidth-bound, so
the extra passes are pure roofline loss. This kernel fuses passes 1+2: each
grid program computes the weighted-median anchor for its d-tile (reusing
``wcwmed.wmed_tile`` on the (m, bd) VMEM tile) and immediately accumulates
each worker's squared distance to that tile of the anchor into a revisited
(m, 1) output block — the distance pass piggybacks on the tile already in
VMEM instead of re-reading HBM. The m-element sort / prefix-sum / weight
clipping stays in XLA (O(m log m) scalars), and a single trimmed-combine pass
finishes:

    pass 1  wctma_anchor_dist   X -> anchor, distances (reads X ONCE)
    pass 2  wcomb_padded        X, kept -> trimmed mean

Total: X is read from HBM exactly twice per call, and the zero-pad copy (when
d is not a tile multiple) happens once for both passes (see pad.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pad import pad_cols
from .wcwmed import wmed_tile
from .wreduce import wcomb_padded

# Wider tiles than the standalone median kernel: both fused passes are
# bandwidth-bound streams, and the (m, bd) f32 working set at m=64, bd=2048
# is ~0.5 MB — comfortably double-bufferable in 16 MB VMEM.
DEFAULT_BLOCK_D = 2048


def _anchor_dist_kernel(x_ref, s_ref, anchor_ref, dist_ref, *, m: int):
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (m, bd)
    s = s_ref[...].astype(jnp.float32)          # (m, 1)

    med = wmed_tile(x, s, m)                    # (bd,) anchor for this tile
    anchor_ref[...] = med

    part = jnp.sum(jnp.square(x - med[None, :]), axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.zeros_like(dist_ref)

    dist_ref[...] += part


def wctma_anchor_dist(xp: jnp.ndarray, s: jnp.ndarray, bd: int, *,
                      interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single sweep over a pre-padded (m, dp) matrix returning
    (anchor (dp,), squared distances (m,))."""
    m, dp = xp.shape
    anchor, dist = pl.pallas_call(
        functools.partial(_anchor_dist_kernel, m=m),
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda j: (0, j)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bd,), lambda j: (j,)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, s.astype(jnp.float32)[:, None])
    return anchor, dist[:, 0]


def trim_weights(dist: jnp.ndarray, s: jnp.ndarray, lam: float
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """CTMA weight trimming (XLA, O(m log m) scalars): keep the (1-λ) weight
    mass of rows closest to the anchor, clipping the boundary row. ``dist``
    only needs to order correctly, so squared distances work. Returns
    (kept (m,), thresh ())."""
    sw = s.astype(jnp.float32)
    order = jnp.argsort(dist)
    ws = sw[order]
    cum = jnp.cumsum(ws)
    thresh = (1.0 - lam) * cum[-1]
    prev = jnp.concatenate([jnp.zeros_like(cum[:1]), cum[:-1]])
    kept_sorted = jnp.clip(thresh - prev, 0.0, ws)
    kept = jnp.zeros_like(kept_sorted).at[order].set(kept_sorted)
    return kept, thresh


@functools.partial(jax.jit, static_argnames=("lam", "block_d", "interpret"))
def wctma_fused(x: jnp.ndarray, s: jnp.ndarray, *, lam: float,
                block_d: int = DEFAULT_BLOCK_D, interpret: bool = True
                ) -> jnp.ndarray:
    """Fused ω-CTMA: x (m, d), s (m,) -> (d,) float32. ≡ ref.wctma_ref."""
    xp, d, bd = pad_cols(x, block_d)
    _, dist = wctma_anchor_dist(xp, s, bd, interpret=interpret)
    kept, thresh = trim_weights(dist, s, lam)
    out = wcomb_padded(xp, kept, jnp.maximum(thresh, 1e-30), bd,
                       interpret=interpret)
    return out[:d]
