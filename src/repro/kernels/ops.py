"""Public jit'd wrappers around the Pallas kernels.

``interpret=True`` (the default in this CPU container) runs the kernel bodies
in the Pallas interpreter for correctness validation; on a real TPU deployment
pass ``interpret=False`` to emit Mosaic kernels. ``use_pallas=False`` falls
back to the pure-jnp oracle — the path the multi-pod dry-run lowers.

HBM-pass accounting for the (m, d) update matrix X (see wctma_fused.py):

    wcwmed          1 pass
    wgm             1 (anchor) + 2·iters (fused dist+combine step), ONE traced
                    loop body via lax.fori_loop — previously the python loop
                    unrolled 2·iters separate pallas_call launches (and a pad
                    copy each) into every trace
    wctma fused     2 passes (anchor+dist fused, then trimmed combine)
    wctma unfused   ≥3 passes (kept for benchmarking the fusion win)
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import ref
from .pad import pad_cols
from .wcwmed import wcwmed_pallas, wcwmed_padded
from .wreduce import gm_step_padded, sqdist_pallas, wcomb_padded, wcomb_pallas
from .wctma_fused import (DEFAULT_BLOCK_D as FUSED_BLOCK_D, trim_weights,
                          wctma_fused)
from .swa import (paged_decode_pallas, ragged_paged_decode_pallas,
                  swa_decode_pallas)


@partial(jax.jit, static_argnames=("interpret",))
def wmean(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *,
          interpret: bool = True) -> jnp.ndarray:
    """Weighted mean of (m, d) rows via the single-pass combine kernel."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    xp, d, bd = pad_cols(x, FUSED_BLOCK_D)
    return wcomb_padded(xp, s, jnp.sum(s.astype(jnp.float32)), bd,
                        interpret=interpret)[:d]


def wcwmed(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *,
           use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    """Weighted coordinate-wise median of (m, d) rows."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    if not use_pallas:
        return ref.wcwmed_ref(x, s)
    return wcwmed_pallas(x, s, interpret=interpret)


@partial(jax.jit, static_argnames=("iters", "eps", "interpret"))
def _wgm_pallas(x: jnp.ndarray, s: jnp.ndarray, *, iters: int, eps: float,
                interpret: bool) -> jnp.ndarray:
    """ω-GM: wcwmed anchor + ``iters`` fused Weiszfeld steps.

    X is padded ONCE (pad.py) and the fused dist+reweight+combine kernel is
    the body of a ``lax.fori_loop`` — trace size and launch count in the
    jaxpr are independent of ``iters``.
    """
    xp, d, bd = pad_cols(x, FUSED_BLOCK_D)
    y0 = wcwmed_padded(xp, s, bd, interpret=interpret)     # (dp,), pad cols -> 0

    def body(_, y):
        return gm_step_padded(xp, s, y, bd, eps=eps, interpret=interpret)

    y = jax.lax.fori_loop(0, iters, body, y0)
    return y[:d]


def wgm(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *, iters: int = 8,
        eps: float = 1e-8, use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    """ω-GM via Weiszfeld: fused kernelized distance+reweight+combine loop."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    if not use_pallas:
        return ref.wgm_ref(x, s, iters=iters)
    return _wgm_pallas(x, s, iters=iters, eps=eps, interpret=interpret)


def wctma(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *, lam: float,
          use_pallas: bool = True, interpret: bool = True,
          fused: bool = True) -> jnp.ndarray:
    """ω-CTMA (Alg. 1). ``fused=True`` (default) computes anchor + distances
    in one grid sweep (2 total HBM passes over X); ``fused=False`` keeps the
    original anchor→sqdist→combine 3-pass pipeline for benchmarking."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    if not use_pallas:
        return ref.wctma_ref(x, s, lam)
    if fused:
        return wctma_fused(x, s, lam=lam, interpret=interpret)
    x0 = wcwmed(x, s, use_pallas=True, interpret=interpret)
    dist = sqdist_pallas(x, x0, interpret=interpret)
    kept, thresh = trim_weights(dist, s, lam)
    return wcomb_pallas(x, kept, jnp.maximum(thresh, 1e-30), interpret=interpret)


@partial(jax.jit, static_argnames=("lam", "iters", "interpret"))
def _wctma_gm_pallas(x: jnp.ndarray, s: jnp.ndarray, *, lam: float,
                     iters: int = 32, interpret: bool) -> jnp.ndarray:
    """ω-CTMA with a GM anchor: shares one padded copy of X across the GM
    loop, the anchor-distance pass and the trimmed combine."""
    xp, d, bd = pad_cols(x, FUSED_BLOCK_D)
    y = wcwmed_padded(xp, s, bd, interpret=interpret)

    def body(_, yy):
        return gm_step_padded(xp, s, yy, bd, interpret=interpret)

    y = jax.lax.fori_loop(0, iters, body, y)
    from .wreduce import sqdist_padded
    dist = sqdist_padded(xp, y, bd, interpret=interpret)
    kept, thresh = trim_weights(dist, s, lam)
    return wcomb_padded(xp, kept, jnp.maximum(thresh, 1e-30), bd,
                        interpret=interpret)[:d]


def wctma_gm(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *, lam: float,
             iters: int = 32, interpret: bool = True) -> jnp.ndarray:
    """ω-CTMA anchored at the weighted geometric median (shared padded X)."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    return _wctma_gm_pallas(x, s, lam=lam, iters=iters, interpret=interpret)


def make_kernel_aggregator(spec: str, lam: float = 0.0, *,
                           interpret: bool = True
                           ) -> Callable[[jnp.ndarray, Optional[jnp.ndarray]], jnp.ndarray]:
    """Deprecated: use ``repro.agg.resolve(spec, backend="pallas")`` — the
    resolved callable also accepts stacked pytrees, and rules without a fused
    pipeline degrade to the jnp oracle exactly as this factory did."""
    warnings.warn("make_kernel_aggregator is deprecated; use "
                  "repro.agg.resolve(spec, lam=..., backend='pallas')",
                  DeprecationWarning, stacklevel=2)
    from repro.agg import resolve
    return resolve(spec, lam=lam, backend="pallas", interpret=interpret)


def swa_decode(q, k_cache, v_cache, pos, *, local: bool,
               use_pallas: bool = True, interpret: bool = True):
    """Flash single-token decode over a (ring) KV cache; ``pos`` scalar or
    (B,) per-slot."""
    if not use_pallas:
        return ref.swa_decode_ref(q, k_cache, v_cache, pos, local=local)
    return swa_decode_pallas(q, k_cache, v_cache, pos, local=local, interpret=interpret)


def paged_decode(q, k_pool, v_pool, page_table, pos, *,
                 use_pallas: bool = True, interpret: bool = True):
    """Per-slot paged flash decode over a block-table KV page pool (global
    causal layers; see serve/cache.py for the pool/table layout)."""
    if not use_pallas:
        return ref.paged_decode_ref(q, k_pool, v_pool, page_table, pos)
    return paged_decode_pallas(q, k_pool, v_pool, page_table, pos,
                               interpret=interpret)


def ragged_paged_decode(q, k_pool, v_pool, page_table, cu_q_lens, q_lens,
                        kv_lens, *, use_pallas: bool = True,
                        interpret: bool = True):
    """Ragged paged attention over a mixed chunked-prefill/decode batch: row
    ``s`` owns packed q tokens ``[cu_q_lens[s], cu_q_lens[s] + q_lens[s])``
    at context depth ``kv_lens[s]`` (see kernels/swa.py for the contract)."""
    if not use_pallas:
        return ref.ragged_paged_decode_ref(q, k_pool, v_pool, page_table,
                                           cu_q_lens, q_lens, kv_lens)
    return ragged_paged_decode_pallas(q, k_pool, v_pool, page_table,
                                      cu_q_lens, q_lens, kv_lens,
                                      interpret=interpret)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int, *, use_pallas: bool = True,
             interpret: bool = True):
    """Mamba-2 SSD scan: Pallas intra-chunk kernel + XLA inter-chunk
    recurrence. Semantics identical to models.ssm.ssd_chunked."""
    if not use_pallas:
        return ref.ssd_ref(x, dt, A, Bm, Cm, chunk)
    from .ssd import ssd_intra_pallas

    y_diag, states, chunk_decay = ssd_intra_pallas(x, dt, A, Bm, Cm,
                                                   chunk=chunk, interpret=interpret)
    b, s, h, p = x.shape
    nc = s // chunk
    n = Bm.shape[-1]

    s0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp
        return carry * dec[..., None, None] + st, carry

    last, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (b, nc, h, p, n)

    a = (dt * A[None, None, :]).reshape(b, nc, chunk, h)
    a_cum = jnp.cumsum(jnp.moveaxis(a, -1, -2), axis=-1)    # (b, nc, h, c)
    state_decay = jnp.exp(a_cum)
    Cc = Cm.reshape(b, nc, chunk, n)
    y_off = jnp.einsum("bzcn,bzhpn,bzhc->bzchp", Cc, prev_states, state_decay)
    y = y_diag + y_off.reshape(b, s, h, p)
    return y, last
