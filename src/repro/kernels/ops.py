"""Public jit'd wrappers around the Pallas kernels.

``interpret=True`` (the default in this CPU container) runs the kernel bodies
in the Pallas interpreter for correctness validation; on a real TPU deployment
pass ``interpret=False`` to emit Mosaic kernels. ``use_pallas=False`` falls
back to the pure-jnp oracle — the path the multi-pod dry-run lowers.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from . import ref
from .wcwmed import wcwmed_pallas
from .wreduce import sqdist_pallas, wcomb_pallas
from .swa import swa_decode_pallas


def wcwmed(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *,
           use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    """Weighted coordinate-wise median of (m, d) rows."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    if not use_pallas:
        return ref.wcwmed_ref(x, s)
    return wcwmed_pallas(x, s, interpret=interpret)


def wgm(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *, iters: int = 8,
        eps: float = 1e-8, use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    """ω-GM via Weiszfeld: kernelized distance pass + reweighted combine."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    if not use_pallas:
        return ref.wgm_ref(x, s, iters=iters)
    y = wcwmed(x, s, use_pallas=True, interpret=interpret)
    for _ in range(iters):
        dist = jnp.sqrt(jnp.maximum(sqdist_pallas(x, y, interpret=interpret), 0.0))
        invd = s.astype(jnp.float32) / jnp.maximum(dist, eps)
        y = wcomb_pallas(x, invd, jnp.sum(invd), interpret=interpret)
    return y


def wctma(x: jnp.ndarray, s: Optional[jnp.ndarray] = None, *, lam: float,
          use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    """ω-CTMA (Alg. 1): anchor (kernel) + distances (kernel) + trimmed combine
    (kernel); the m-element sort/prefix stays in XLA — it is O(m log m) scalars."""
    if s is None:
        s = jnp.ones((x.shape[0],), jnp.float32)
    if not use_pallas:
        return ref.wctma_ref(x, s, lam)
    x0 = wcwmed(x, s, use_pallas=True, interpret=interpret)
    dist = sqdist_pallas(x, x0, interpret=interpret)
    order = jnp.argsort(dist)
    sw = s.astype(jnp.float32)[order]
    cum = jnp.cumsum(sw)
    thresh = (1.0 - lam) * cum[-1]
    prev = jnp.concatenate([jnp.zeros_like(cum[:1]), cum[:-1]])
    kept_sorted = jnp.clip(thresh - prev, 0.0, sw)
    kept = jnp.zeros_like(kept_sorted).at[order].set(kept_sorted)
    return wcomb_pallas(x, kept, thresh, interpret=interpret)


def swa_decode(q, k_cache, v_cache, pos, *, local: bool,
               use_pallas: bool = True, interpret: bool = True):
    """Flash single-token decode over a (ring) KV cache."""
    if not use_pallas:
        return ref.swa_decode_ref(q, k_cache, v_cache, pos, local=local)
    return swa_decode_pallas(q, k_cache, v_cache, pos, local=local, interpret=interpret)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int, *, use_pallas: bool = True,
             interpret: bool = True):
    """Mamba-2 SSD scan: Pallas intra-chunk kernel + XLA inter-chunk
    recurrence. Semantics identical to models.ssm.ssd_chunked."""
    if not use_pallas:
        return ref.ssd_ref(x, dt, A, Bm, Cm, chunk)
    from .ssd import ssd_intra_pallas

    y_diag, states, chunk_decay = ssd_intra_pallas(x, dt, A, Bm, Cm,
                                                   chunk=chunk, interpret=interpret)
    b, s, h, p = x.shape
    nc = s // chunk
    n = Bm.shape[-1]

    import jax
    s0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp
        return carry * dec[..., None, None] + st, carry

    last, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (b, nc, h, p, n)

    a = (dt * A[None, None, :]).reshape(b, nc, chunk, h)
    a_cum = jnp.cumsum(jnp.moveaxis(a, -1, -2), axis=-1)    # (b, nc, h, c)
    state_decay = jnp.exp(a_cum)
    Cc = Cm.reshape(b, nc, chunk, n)
    y_off = jnp.einsum("bzcn,bzhpn,bzhc->bzchp", Cc, prev_states, state_decay)
    y = y_diag + y_off.reshape(b, s, h, p)
    return y, last
