"""Pallas TPU kernels for the compute hot-spots (see kernels/README.md).

Public entry points live in ``ops.py`` (jit'd wrappers with ``use_pallas`` /
``interpret`` switches); ``ref.py`` holds the pure-jnp oracles every kernel
is swept against in tests/test_kernels.py.
"""
