"""μ²-SGD with AnyTime averaging (paper §3) + momentum/SGD baselines."""
from .mu2sgd import (  # noqa: F401
    OptConfig,
    OptState,
    anytime_coeff,
    init_opt,
    opt_query_points,
    opt_update,
)
