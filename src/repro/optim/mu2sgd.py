"""μ²-SGD (Levy 2023) and baseline optimizers with a unified pytree API.

μ²-SGD maintains three sequences:
  - ``w_t``  : projected-SGD iterates,
  - ``x_t``  : AnyTime weighted average of the iterates (the *query* point),
  - ``d_t``  : corrected-momentum gradient estimate at ``x_t``.

Server update (paper Alg. 2 line 7, α_t = t):
    w_{t+1} = Π_K( w_t - η α_t d̂_t ),     x_{t+1} = x_t + α_{t+1}/α_{1:t+1} (w_{t+1} - x_t)

Corrected momentum (worker side, β_t = 1/s_t):
    d_t = g(x_t; z_t) + (1 - β_t) (d_{t-1} - g(x_{t-1}; z_t))

Both the theory schedule (α_t = t, β_t = 1/s_t) and the paper's practical
constant-coefficient variant (γ = α_t/α_{1:t} fixed, β fixed — Appendix D) are
supported. The API is deliberately split so a *train step* owns the gradient
evaluations (μ² needs the gradient at two points with the SAME sample):

    x_t, x_prev = opt_query_points(state)
    g       = grad(loss)(x_t, batch)
    g_tilde = grad(loss)(x_prev, batch)     # only used by mu2
    state   = opt_update(cfg, state, g, g_tilde)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class OptConfig(NamedTuple):
    name: str = "mu2"          # mu2 | momentum | sgd
    lr: float = 0.01
    beta: Optional[float] = None   # mu2: constant β (None -> 1/t); momentum: Polyak β
    gamma: Optional[float] = None  # mu2: constant AnyTime γ (None -> α_t = t schedule)
    proj_radius: Optional[float] = None  # L2 ball around init (paper's compact K)
    weight_decay: float = 0.0
    # Memory optimization (beyond-paper, see EXPERIMENTS.md §Perf): the AnyTime
    # recursion x_t = (1-γ_t) x_{t-1} + γ_t w_t is exactly invertible, so the
    # previous query point need not be stored — recompute x_{t-1} from (x_t, w_t).
    implicit_x_prev: bool = False


class OptState(NamedTuple):
    w: Pytree                  # iterate
    x: Pytree                  # query point (mu2: AnyTime average; else == w)
    x_prev: Pytree             # previous query point (mu2 correction)
    d: Pytree                  # corrected momentum / momentum buffer
    t: jnp.ndarray             # int32 step counter (0-based before first update)
    anchor: Pytree             # init point for projection


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def init_opt(cfg: OptConfig, params: Pytree) -> OptState:
    zeros = _tmap(jnp.zeros_like, params)
    copy = _tmap(lambda x: x.copy(), params)
    x_prev = None if (cfg.implicit_x_prev or cfg.name != "mu2") else _tmap(lambda x: x.copy(), params)
    anchor = _tmap(lambda x: x.copy(), params) if cfg.proj_radius is not None else None
    return OptState(w=params, x=copy, x_prev=x_prev, d=zeros,
                    t=jnp.zeros((), jnp.int32), anchor=anchor)


def opt_query_points(cfg: OptConfig, state: OptState) -> tuple[Pytree, Pytree]:
    """Points at which the train step must evaluate gradients (x_t, x_{t-1}).

    With ``implicit_x_prev``, inverts the AnyTime recursion instead of reading
    a stored copy: x_{t-1} = (x_t - γ_t w_t) / (1 - γ_t).
    """
    if cfg.name != "mu2":
        return state.w, state.w
    if not cfg.implicit_x_prev:
        return state.x, state.x_prev
    gc = anytime_coeff(state.t + 1, cfg.gamma)
    first = state.t == 0

    def inv(xl, wl):
        rec = (xl.astype(jnp.float32) - gc * wl.astype(jnp.float32)) / (1.0 - gc)
        return jnp.where(first, xl, rec.astype(xl.dtype))

    return state.x, _tmap(inv, state.x, state.w)


def anytime_coeff(t_next: jnp.ndarray, gamma: Optional[float]) -> jnp.ndarray:
    """γ_t = α_t / α_{1:t} for the x-average update at step t_next (1-based)."""
    if gamma is not None:
        return jnp.asarray(gamma, jnp.float32)
    tf = t_next.astype(jnp.float32)
    return 2.0 * tf / (tf * (tf + 1.0))  # α_t = t ⇒ α_{1:t} = t(t+1)/2


def _project(cfg: OptConfig, w: Pytree, anchor: Pytree) -> Pytree:
    if cfg.proj_radius is None:
        return w
    diff = _tmap(jnp.subtract, w, anchor)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree_util.tree_leaves(diff))
    norm = jnp.sqrt(jnp.maximum(sq, 1e-30))
    scale = jnp.minimum(1.0, cfg.proj_radius / norm)
    return _tmap(lambda a, dl: a + scale * dl, anchor, diff)


def corrected_momentum(cfg: OptConfig, d_prev: Pytree, g: Pytree, g_tilde: Pytree,
                       count: jnp.ndarray) -> Pytree:
    """d = g + (1-β)(d_prev - g_tilde), β = 1/count unless constant."""
    beta = (jnp.asarray(cfg.beta, jnp.float32) if cfg.beta is not None
            else 1.0 / jnp.maximum(count.astype(jnp.float32), 1.0))
    first = count <= 1  # d_1 = g_1
    return _tmap(lambda gl, dl, gtl: jnp.where(first, gl, gl + (1.0 - beta) * (dl - gtl)),
                 g, d_prev, g_tilde)


def server_step(cfg: OptConfig, state: OptState, d_hat: Pytree, lr_scale=1.0) -> OptState:
    """Apply the AnyTime server update with an (aggregated) estimate d̂_t."""
    t_next = state.t + 1
    alpha = (jnp.asarray(1.0, jnp.float32) if cfg.gamma is not None
             else t_next.astype(jnp.float32))
    step_size = cfg.lr * lr_scale * alpha
    w_new = _tmap(lambda wl, dl: (wl - step_size * dl.astype(wl.dtype)
                                  - cfg.lr * cfg.weight_decay * wl), state.w, d_hat)
    w_new = _project(cfg, w_new, state.anchor)
    gcoef = anytime_coeff(t_next + 1, cfg.gamma)
    x_new = _tmap(lambda xl, wl: xl + gcoef.astype(xl.dtype) * (wl - xl), state.x, w_new)
    x_prev = None if cfg.implicit_x_prev else state.x
    return OptState(w=w_new, x=x_new, x_prev=x_prev, d=state.d, t=t_next,
                    anchor=state.anchor)


def opt_update(cfg: OptConfig, state: OptState, g: Pytree,
               g_tilde: Optional[Pytree] = None, lr_scale=1.0) -> OptState:
    """Single-worker (synchronous, m=1) update for all supported optimizers."""
    t_next = state.t + 1
    # cfg.weight_decay applies to EVERY optimizer, with the same decoupled
    # -lr·wd·w term server_step uses (the sgd/momentum branches used to drop
    # it silently, so sweeps comparing optimizers at wd>0 were inconsistent).
    if cfg.name == "sgd":
        w = _tmap(lambda wl, gl: (wl - cfg.lr * lr_scale * gl.astype(wl.dtype)
                                  - cfg.lr * cfg.weight_decay * wl), state.w, g)
        w = _project(cfg, w, state.anchor)
        return OptState(w=w, x=w, x_prev=None, d=state.d, t=t_next, anchor=state.anchor)
    if cfg.name == "momentum":
        beta = 0.9 if cfg.beta is None else cfg.beta
        d = _tmap(lambda dl, gl: beta * dl + (1.0 - beta) * gl, state.d, g)
        w = _tmap(lambda wl, dl: (wl - cfg.lr * lr_scale * dl.astype(wl.dtype)
                                  - cfg.lr * cfg.weight_decay * wl), state.w, d)
        w = _project(cfg, w, state.anchor)
        return OptState(w=w, x=w, x_prev=None, d=d, t=t_next, anchor=state.anchor)
    if cfg.name == "mu2":
        assert g_tilde is not None, "mu2 requires the gradient at x_prev on the same batch"
        d = corrected_momentum(cfg, state.d, g, g_tilde, t_next)
        new = server_step(cfg, state._replace(d=d), d, lr_scale)
        return new._replace(d=d)
    raise KeyError(f"unknown optimizer {cfg.name}")
