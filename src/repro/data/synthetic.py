"""Deterministic synthetic data pipelines.

MNIST/CIFAR-10 are unavailable offline (DESIGN.md §7); the classification
stream substitutes a 10-class Gaussian-mixture image problem with the same
tensor shapes, and the LM stream uses a learnable affine-recurrence token
process (next token is a fixed function of the current one plus noise) so
training losses genuinely decrease.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


def make_classification_data(n: int, image_hw=(28, 28), channels=1, n_classes=10,
                             seed=0, sigma=1.0, sample_seed: Optional[int] = None):
    """Gaussian mixture: class c has a mean pattern drawn once from ``seed``;
    samples are drawn from ``sample_seed`` (defaults to seed) — pass a
    different sample_seed for a held-out test split of the SAME distribution."""
    rng_mean = np.random.default_rng(seed)
    rng = np.random.default_rng(seed if sample_seed is None else sample_seed)
    H, W = image_hw
    means = rng_mean.normal(0.0, 1.0, size=(n_classes, H, W, channels)).astype(np.float32)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = means[y] + sigma * rng.normal(size=(n, H, W, channels)).astype(np.float32)
    return {"x": x, "y": y}


def classification_batches(batch_size: int, *, image_hw=(28, 28), channels=1,
                           n_classes=10, seed=0, sigma=1.0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    H, W = image_hw
    means = rng.normal(0.0, 1.0, size=(n_classes, H, W, channels)).astype(np.float32)
    while True:
        y = rng.integers(0, n_classes, size=batch_size).astype(np.int32)
        x = means[y] + sigma * rng.normal(size=(batch_size, H, W, channels)).astype(np.float32)
        yield {"x": x, "y": y}


def worker_batches(m: int, batch_size: int, **kw) -> dict:
    """One init minibatch per worker (leading axis m) — engine initialization."""
    it = classification_batches(m * batch_size, **kw)
    b = next(it)
    return {"x": b["x"].reshape(m, batch_size, *b["x"].shape[1:]),
            "y": b["y"].reshape(m, batch_size)}


def dirichlet_class_probs(m: int, n_classes: int, alpha: float,
                          seed: int = 0) -> np.ndarray:
    """Per-worker label distributions for the Fixing-by-Mixing heterogeneous
    regime: worker i draws its labels from ``p_i ~ Dirichlet(alpha · 1_C)``.

    Small ``alpha`` concentrates each worker on a few classes (strong label
    skew); large ``alpha`` approaches uniform; ``alpha = inf`` returns the
    exact IID uniform distribution. Returns an ``(m, n_classes)`` row-
    stochastic matrix, deterministic in ``(m, n_classes, alpha, seed)``."""
    if not np.isfinite(alpha):
        return np.full((m, n_classes), 1.0 / n_classes, np.float64)
    if alpha <= 0:
        raise ValueError(f"Dirichlet alpha must be > 0 (or inf for IID), "
                         f"got {alpha}")
    rng = np.random.default_rng([seed, 0xD1F])
    return rng.dirichlet(np.full(n_classes, float(alpha)), size=m)


def heterogeneous_worker_batches(m: int, batch_size: int, *,
                                 alpha: float = np.inf, image_hw=(28, 28),
                                 channels=1, n_classes=10, seed=0, sigma=1.0,
                                 sample_seed: Optional[int] = None,
                                 shard_seed: Optional[int] = None
                                 ) -> Iterator[dict]:
    """Per-worker batch stacks under Dirichlet label skew.

    Yields ``{"x": (m, B, H, W, C), "y": (m, B)}`` — one minibatch PER WORKER
    per step, worker i's labels drawn from its own Dirichlet(alpha) class
    distribution over the SAME class-mean patterns as
    :func:`make_classification_data` (mean seed = ``seed``, so an IID test
    split from ``make_classification_data`` evaluates every heterogeneity
    level on one distribution). ``alpha = inf`` degenerates to IID workers.
    ``sample_seed`` (defaults to ``seed``) seeds the sample stream and
    ``shard_seed`` the per-worker Dirichlet draw, each as independent
    substreams, so fleet scenarios can vary their data stream without moving
    the class-mean patterns (and vice versa)."""
    rng_mean = np.random.default_rng(seed)
    rng = np.random.default_rng(
        [seed if sample_seed is None else sample_seed, 0x5A17])
    H, W = image_hw
    means = rng_mean.normal(0.0, 1.0,
                            size=(n_classes, H, W, channels)).astype(np.float32)
    probs = dirichlet_class_probs(m, n_classes, alpha,
                                  seed if shard_seed is None else shard_seed)
    cum = np.cumsum(probs, axis=1)          # (m, C) inverse-CDF sampling
    while True:
        u = rng.random((m, batch_size))
        y = (u[:, :, None] > cum[:, None, :]).sum(-1).astype(np.int32)
        noise = rng.normal(size=(m, batch_size, H, W, channels))
        x = means[y] + sigma * noise.astype(np.float32)
        yield {"x": x.astype(np.float32), "y": y}


def _lm_stream(rng: np.random.Generator, batch: int, seq: int, vocab: int,
               noise: float = 0.05) -> np.ndarray:
    """t_{i+1} = (a * t_i + b) mod V with occasional noise — learnable."""
    a, b = 31, 17
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    for i in range(seq):
        nxt = (a * toks[:, i] + b) % vocab
        flip = rng.random(batch) < noise
        nxt = np.where(flip, rng.integers(0, vocab, size=batch), nxt)
        toks[:, i + 1] = nxt
    return toks


def lm_batches(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
               ) -> Iterator[dict]:
    """Batches matching the model's frontend (tokens / frames+labels / patches)."""
    rng = np.random.default_rng(seed)
    while True:
        if cfg.frontend == "audio":
            frames = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
            labels = rng.integers(0, cfg.vocab, size=(batch, seq)).astype(np.int32)
            yield {"frames": frames, "labels": labels}
            continue
        toks = _lm_stream(rng, batch, seq, cfg.vocab)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend == "vision":
            out["patches"] = rng.normal(size=(batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
        yield out
