"""Synthetic data: classification / LM / per-worker batch generators."""
from .synthetic import (  # noqa: F401
    classification_batches,
    dirichlet_class_probs,
    heterogeneous_worker_batches,
    lm_batches,
    make_classification_data,
    worker_batches,
)
