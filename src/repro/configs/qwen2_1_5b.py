"""qwen2-1.5b [arXiv:2407.10671] — dense GQA with QKV bias.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=2, d_ff=512,
                     vocab=1024, dtype="float32", remat=False)
