"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — dense MHA (kv == heads), qwen1.5 arch.

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    fsdp=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=4, d_ff=512,
                     vocab=1024, dtype="float32", remat=False)
