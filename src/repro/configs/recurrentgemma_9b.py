"""recurrentgemma-9b [arXiv:2402.19427] — RG-LRU + local attention, 1 attn : 2 rec.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window=2048.
Sub-quadratic: runs the long_500k decode shape.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=("rec", "rec", "local"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    fsdp=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=3, d_model=256, n_heads=4, n_kv=1, head_dim=64,
                     d_ff=512, vocab=1024, lru_width=256, window=32,
                     dtype="float32", remat=False)
