"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts top-4 + 4 shared.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    n_experts=60,
    top_k=4,
    n_shared=4,
    d_expert=1408,
    moe_shard="tp",  # 60 experts don't divide the 16-way model axis
    moe_dispatch="sharded",
    fsdp=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=4, d_ff=128,
                     n_experts=4, top_k=2, n_shared=1, d_expert=128,
                     vocab=1024, dtype="float32", remat=False)
