"""gemma3-4b [hf:google/gemma-3-1b-pt family] — 5 local : 1 global, 128k context.

34L d_model=2560 8H (GQA kv=4, head_dim=256) d_ff=10240 vocab=262144.
Sliding-window local layers (window=1024) make long_500k decode feasible.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    qk_norm=True,
    window=1024,
    global_every=6,        # pattern: 5 local then 1 global
    rope_theta=1_000_000.0,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=6, d_model=256, n_heads=4, n_kv=2, head_dim=64,
                     d_ff=512, vocab=1024, window=32, global_every=3,
                     dtype="float32", remat=False)
