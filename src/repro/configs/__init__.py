"""Architecture registry + assigned input shapes.

``get_config(name)`` returns the full production config; ``smoke_config(name)``
the reduced same-family variant used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, NamedTuple

from repro.models.config import ModelConfig

_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma3-4b": "gemma3_4b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "gemma3-27b": "gemma3_27b",
    "internvl2-1b": "internvl2_1b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "mamba2-1.3b": "mamba2_1_3b",
}

ARCH_NAMES = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _mod(name).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


class InputShape(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

SHAPE_NAMES = tuple(SHAPES)


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether the (arch, shape) combination runs, with the skip reason."""
    sh = SHAPES[shape]
    if sh.mode == "decode":
        if not cfg.supports_decode():
            return False, "encoder-only architecture has no autoregressive decode"
        if shape == "long_500k" and not cfg.is_subquadratic():
            return False, "full-attention architecture; 500k KV decode requires a sub-quadratic variant"
    return True, ""
