"""The paper's own experimental model: a two-conv-layer CNN classifier
(Appendix D, Table 2) — Conv(C,20,5) → ReLU → MaxPool → Conv(20,50,5) → ReLU
→ MaxPool → FC(→50) → norm → ReLU → FC(→10).

MNIST/CIFAR-10 are unavailable offline; the data pipeline substitutes a
deterministic 10-class Gaussian-mixture image dataset of the same shapes
(28×28×1 / 32×32×3). See repro.models.classifier for the implementation.
"""
from repro.models.classifier import ClassifierConfig

MNIST_LIKE = ClassifierConfig(name="paper-cnn-mnist", kind="cnn",
                              image_hw=(28, 28), channels=1, n_classes=10)
CIFAR_LIKE = ClassifierConfig(name="paper-cnn-cifar", kind="cnn",
                              image_hw=(32, 32), channels=3, n_classes=10)
MLP_SMALL = ClassifierConfig(name="paper-mlp", kind="mlp",
                             image_hw=(8, 8), channels=1, n_classes=10,
                             mlp_hidden=(64,))
