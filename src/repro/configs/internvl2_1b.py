"""internvl2-1b [arXiv:2404.16821] — InternViT (stub) + Qwen2-0.5B-class LLM.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The vision encoder +
projector are stubs per the assignment carve-out: batches carry 256
precomputed patch embeddings of width d_model prepended to the text tokens.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    frontend="vision",
    n_patches=256,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=2, d_ff=512,
                     vocab=1024, n_patches=8, dtype="float32", remat=False)
