"""hubert-xlarge [arXiv:2106.07447] — audio encoder backbone (w2v2 arch).

48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504. Encoder-only: no
autoregressive decode (decode shapes are skipped, see DESIGN.md). The
mel/conv feature extractor is a stub — batches carry frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend="audio",
    tie_embeddings=False,  # 504-dim masked-unit prediction head
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=4, d_ff=512,
                     dtype="float32", remat=False)
