"""gemma3-27b [hf:google/gemma-3-1b-pt family] — 5 local : 1 global, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    qk_norm=True,
    window=1024,
    global_every=6,
    rope_theta=1_000_000.0,
    fsdp=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=6, d_model=256, n_heads=4, n_kv=2, head_dim=64,
                     d_ff=512, vocab=1024, window=32, global_every=3,
                     dtype="float32", remat=False)
