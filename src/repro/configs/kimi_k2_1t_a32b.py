"""kimi-k2-1t-a32b [arXiv:2501.kimi2] — trillion-parameter MoE (paper-table).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert) vocab=163840,
384 routed experts top-8 + 1 shared. ~1.03T total / ~32B active params.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv=8,
    head_dim=112,
    d_ff=2048,
    vocab=163840,
    n_experts=384,
    top_k=8,
    n_shared=1,
    d_expert=2048,
    capacity_factor=1.25,
    moe_dispatch="sharded",
    fsdp=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=256, n_heads=8, n_kv=2, head_dim=32,
                     d_ff=128, n_experts=4, top_k=2, n_shared=1, d_expert=128,
                     vocab=1024, dtype="float32", remat=False)
