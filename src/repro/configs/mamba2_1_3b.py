"""mamba2-1.3b [arXiv:2405.21060] — SSD (state-space duality), attention-free.

48L d_model=2048 vocab=50280, ssm_state=128, expand=2 (d_inner=4096),
head_dim=64 (64 SSD heads). Sub-quadratic: runs the long_500k decode shape.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=256, d_ff=0, vocab=1024,
                     ssm_state=32, ssm_head_dim=32, ssm_chunk=16,
                     dtype="float32", remat=False)
